//! Content-hash feature cache: skip the expensive CNN front-end on repeats.
//!
//! The paper's energy split is the whole story here: the CNN front-end
//! costs 96.23 nJ per classification while the ACAM back-end costs 1.45 nJ
//! (PAPER.md).  A repeated image recognised by content hash therefore skips
//! ~98.5% of the modelled energy and nearly all of the compute — but only
//! the *front half*.  The cache stores the **binarised feature vector**
//! (the packed bits the matcher consumes), and the back-end always re-runs
//! against the live template store, so:
//!
//! * template-store hot-swaps (PR 8) serve the new templates on the very
//!   next request, hit or miss;
//! * the degradation ladder (PR 7) scores hits through whatever backend
//!   state the shard is in (`digital_fallback` included);
//! * the ACAM variability model draws from the shard RNG in the same order
//!   on a hit as on a miss, keeping hit-vs-miss predictions bitwise equal.
//!
//! Keys are an FNV-1a 64-bit hash of the raw little-endian pixel bytes —
//! content, not identity, so the same image uploaded twice hits regardless
//! of which connection or batch it arrived in.  Capacity is bounded;
//! eviction picks a seeded-deterministic random victim (no recency
//! bookkeeping on the hot path, reproducible across reruns).  The cached
//! bits are a function of the *current* store's thresholds, so the owner
//! must [`FeatureCache::flush`] whenever the default store's version (or
//! the engine itself) changes.
//!
//! Determinism contract: with the cache **off** nothing here runs — serving
//! is bitwise identical to a build without this module.  With the cache
//! **on**, lookups never touch any RNG shared with scoring; the eviction
//! RNG is private to the cache.

use std::collections::HashMap;

use crate::rng::Rng;

/// FNV-1a 64-bit over raw bytes (the byte-slice sibling of
/// [`crate::coordinator::shard::fnv1a`], which hashes routing-key strings).
pub fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Content hash of an image: FNV-1a over the pixel buffer's little-endian
/// `f32` bytes.  Byte-exact, so `-0.0` and `0.0` hash differently — two
/// buffers collide only when their wire representations are identical,
/// which is exactly when the front-end would produce identical features.
pub fn content_hash(image: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for px in image {
        for b in px.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Bounded map from content hash to the binarised feature vector, with
/// seeded-deterministic random eviction and local hit/miss/eviction
/// counters (the worker copies them into the shared atomic
/// [`crate::coordinator::Metrics`] after each batch).
pub struct FeatureCache {
    capacity: usize,
    map: HashMap<u64, Vec<u8>>,
    /// Insertion-order key list backing O(1) random eviction
    /// (`swap_remove`); always mirrors `map`'s key set.
    keys: Vec<u64>,
    rng: Rng,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl FeatureCache {
    /// `capacity` must be positive (enforced upstream by
    /// `ServeConfig::validate` / `resolve_cache`); `seed` makes the
    /// eviction sequence reproducible (per-shard seeds keep shards'
    /// victim choices independent).
    pub fn new(capacity: usize, seed: u64) -> FeatureCache {
        FeatureCache {
            capacity: capacity.max(1),
            map: HashMap::with_capacity(capacity.max(1).min(4096)),
            keys: Vec::new(),
            rng: Rng::new(seed),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Look up cached binarised bits by content hash, counting the hit or
    /// miss.  Returns a clone (a few dozen bytes — `n_features / 8`), so
    /// the caller never borrows across the subsequent insert.
    pub fn lookup(&mut self, key: u64) -> Option<Vec<u8>> {
        match self.map.get(&key) {
            Some(bits) => {
                self.hits += 1;
                Some(bits.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert freshly-computed bits, evicting one seeded-random resident
    /// entry when at capacity.  Re-inserting a resident key overwrites in
    /// place (no eviction, no growth).
    pub fn insert(&mut self, key: u64, bits: Vec<u8>) {
        if self.map.insert(key, bits).is_some() {
            return; // overwrite: key list already holds it
        }
        self.keys.push(key);
        if self.keys.len() > self.capacity {
            // Evict a random *other* entry: the victim index is drawn over
            // the old residents so the just-inserted key survives.
            let victim_idx = self.rng.below(self.keys.len() - 1);
            let victim = self.keys.swap_remove(victim_idx);
            self.map.remove(&victim);
            self.evictions += 1;
        }
    }

    /// Drop every entry (counters survive — they are monotone totals).
    /// Called on engine rebuild and whenever the default template store's
    /// version changes: cached bits are a function of the store's
    /// binarisation thresholds, so a swap invalidates them all.
    pub fn flush(&mut self) {
        self.map.clear();
        self.keys.clear();
    }

    /// Copy the local counters into the shared atomic metrics (single
    /// writer — the worker thread — so plain `store` is exact).  The cache
    /// outlives worker rebuilds in the shard loop, so the counter totals
    /// stay monotone across panic-restarts while the entries gauge drops to
    /// the post-flush resident count.
    pub fn publish_to(&self, m: &super::Metrics) {
        use std::sync::atomic::Ordering::Relaxed;
        m.cache_hits.store(self.hits, Relaxed);
        m.cache_misses.store(self.misses, Relaxed);
        m.cache_evictions.store(self.evictions, Relaxed);
        m.cache_entries.store(self.len() as u64, Relaxed);
    }

    /// Resident entries (the `hec_cache_entries` gauge).
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_bytes_matches_reference_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a_bytes(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_bytes(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_bytes(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn content_hash_is_byte_exact_over_le_f32() {
        let img = [0.5f32, -1.25, 3.0];
        let mut bytes = Vec::new();
        for px in img {
            bytes.extend_from_slice(&px.to_le_bytes());
        }
        assert_eq!(content_hash(&img), fnv1a_bytes(&bytes));
        // Sign of zero is content: -0.0 differs from 0.0 on the wire.
        assert_ne!(content_hash(&[0.0]), content_hash(&[-0.0]));
        assert_ne!(content_hash(&[0.5, 0.25]), content_hash(&[0.25, 0.5]));
    }

    #[test]
    fn lookup_counts_hits_and_misses() {
        let mut c = FeatureCache::new(8, 1);
        let k = content_hash(&[1.0, 2.0]);
        assert!(c.lookup(k).is_none());
        c.insert(k, vec![0b1010]);
        assert_eq!(c.lookup(k).as_deref(), Some(&[0b1010u8][..]));
        assert_eq!((c.hits, c.misses, c.evictions), (1, 1, 0));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_bounds_and_eviction_is_deterministic() {
        let run = |seed: u64| {
            let mut c = FeatureCache::new(4, seed);
            for i in 0..32u64 {
                c.insert(i, vec![i as u8]);
            }
            let mut resident: Vec<u64> = c.keys.clone();
            resident.sort_unstable();
            (resident, c.evictions, c.len())
        };
        let (r1, ev1, len1) = run(7);
        let (r2, ev2, len2) = run(7);
        assert_eq!(r1, r2, "same seed, same victims");
        assert_eq!(ev1, 32 - 4);
        assert_eq!((len1, len2), (4, 4));
        // A different seed picks a different victim sequence (astronomically
        // likely for 28 draws).
        let (r3, _, _) = run(8);
        assert_ne!(r1, r3);
    }

    #[test]
    fn newest_entry_survives_its_own_eviction() {
        let mut c = FeatureCache::new(2, 3);
        for i in 0..100u64 {
            c.insert(i, vec![]);
            assert!(c.lookup(i).is_some(), "entry {i} evicted itself");
            c.hits = 0; // keep the probe out of the counters under test
        }
    }

    #[test]
    fn reinsert_overwrites_without_eviction() {
        let mut c = FeatureCache::new(2, 1);
        c.insert(1, vec![1]);
        c.insert(2, vec![2]);
        c.insert(1, vec![9]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions, 0);
        assert_eq!(c.lookup(1).as_deref(), Some(&[9u8][..]));
    }

    #[test]
    fn flush_clears_entries_but_keeps_totals() {
        let mut c = FeatureCache::new(4, 1);
        c.insert(1, vec![1]);
        c.lookup(1);
        c.lookup(2);
        c.flush();
        assert!(c.is_empty());
        assert_eq!((c.hits, c.misses), (1, 1));
        assert!(c.lookup(1).is_none(), "flushed entries are gone");
    }
}

"""Dataset generator determinism (golden values shared with the Rust mirror)
and the Eq.-13 MAC ledger / paper-scale constants."""

import numpy as np
from numpy.testing import assert_allclose

from compile import macs
from compile.config import DataConfig
from compile.data import GRAY_WEIGHTS, Lcg, load, synth_dataset, synth_image, to_grayscale


# ---------------------------------------------------------------------------
# LCG / generator golden values — pinned identically in
# rust/src/dataset/synthetic.rs tests; a change on either side breaks both.
# ---------------------------------------------------------------------------


def test_lcg_golden_sequence():
    l = Lcg(42)
    assert [l.next_u64() for l in [l] * 0] == []
    seq = [Lcg(42).next_u64()]
    l = Lcg(42)
    seq = [l.next_u64() for _ in range(4)]
    assert seq == [
        13986908341085854848,
        2827560660634158031,
        776025860801273266,
        301797295797536665,
    ]


def test_lcg_u01_golden():
    assert abs(Lcg(0).u01() - 0.288574626916) < 1e-10


def test_synth_image_golden():
    img = synth_image(3, 7, 0)
    assert img.shape == (32, 32)
    assert abs(float(img.sum()) - 194.83780) < 1e-2
    assert float(img[0, 0]) == 0.0


def test_synth_image_deterministic():
    a = synth_image(5, 11, 3)
    b = synth_image(5, 11, 3)
    assert_allclose(a, b)
    c = synth_image(5, 12, 3)
    assert not np.allclose(a, c)


def test_synth_dataset_round_robin_labels():
    x, y = synth_dataset(25, seed=0)
    assert list(y[:12]) == [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 0, 1]
    assert x.shape == (25, 32, 32, 1)
    assert x.min() >= 0.0 and x.max() <= 1.0


def test_grayscale_weights_are_paper_formula():
    assert_allclose(GRAY_WEIGHTS, [0.2989, 0.5870, 0.1140], rtol=1e-6)
    rgb = np.ones((2, 2, 3), np.float32)
    assert_allclose(to_grayscale(rgb), np.full((2, 2), 0.9999), rtol=1e-4)


def test_load_normalised():
    cfg = DataConfig(train_samples=100, test_samples=40)
    tx, ty, vx, vy, norm = load(cfg)
    assert abs(tx.mean()) < 1e-3 and abs(tx.std() - 1.0) < 1e-2
    assert tx.shape == (100, 32, 32, 1) and vx.shape == (40, 32, 32, 1)


def test_load_color_tiles_channels():
    cfg = DataConfig(train_samples=50, test_samples=20)
    tx, *_ = load(cfg, color=True)
    assert tx.shape == (50, 32, 32, 3)
    assert_allclose(tx[..., 0], tx[..., 1])


# ---------------------------------------------------------------------------
# MAC ledger (Eq. 13)
# ---------------------------------------------------------------------------


def test_conv_macs_eq13():
    l = macs.ConvLayer(h_out=16, w_out=16, kh=3, kw=3, cin=32, cout=128)
    assert l.macs == 16 * 16 * 3 * 3 * 32 * 128


def test_student_macs_layer_breakdown():
    layers = macs.student_layers()
    by_name = {l.name: l for l in layers}
    assert by_name["conv1"].macs == 32 * 32 * 9 * 1 * 32
    assert by_name["conv2"].macs == 16 * 16 * 9 * 32 * 128
    assert by_name["conv3"].macs == 8 * 8 * 9 * 128 * 256
    assert by_name["conv4"].macs == 7 * 7 * 4 * 256 * 16
    assert by_name["head"].macs == 784 * 10


def test_softmax_head_ops_constant():
    """§V.D: removing the head saves 784*10 + 10 = 7,850 ops."""
    head = macs.student_layers()[-1]
    assert head.params == macs.PAPER["softmax_head_ops"] == 7850


def test_paper_constants_internally_consistent():
    p = macs.PAPER
    assert p["frontend_ops_acam"] == round(p["student_opt"]["macs"]) - p["softmax_head_ops"]
    # E_backend = 10 * 784 * 185fJ = 1.4504 nJ
    e_b = p["n_templates"] * p["n_features"] * p["acam_cell_energy_fj"] * 1e-6  # nJ
    assert abs(e_b - p["e_backend_nj"]) < 0.01
    # Student effective MACs = 20% of base MACs (80% sparsity).
    assert abs(p["student_opt"]["macs"] - p["student_base"]["macs"] * 0.2) < 1.0


def test_effective_macs():
    assert macs.effective_macs(1000, 0.8) == 200
    assert macs.effective_macs(23_785_120, 0.8) == 4_757_024


def test_teacher_macs_scale_with_width():
    small = macs.total_macs(macs.teacher_layers(width=8))
    big = macs.total_macs(macs.teacher_layers(width=16))
    assert 3.5 < big / small < 4.5  # MACs ~ width^2

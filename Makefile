# Hybrid edge classifier — build / verify entry points.
#
# `make verify` is the tier-1 gate (what CI's rust job runs); it needs only
# a stock Rust toolchain — the default build has zero external dependencies
# and serves with synthetic weights when no artifacts/ directory exists.

.PHONY: verify test lint fmt artifacts clean

# Tier-1 verification: release build + full test suite.
verify:
	cargo build --release
	cargo test -q

test:
	cargo test -q
	-python -m pytest python/tests -q

# Style gates (CI runs these as separate steps).
lint:
	cargo fmt --check
	cargo clippy --all-targets -- -D warnings

fmt:
	cargo fmt

# Build the AOT artifacts (HLO text modules + templates.json + meta.json).
# Requires the Python training stack (jax + numpy); the Rust serving stack
# runs without artifacts via the synthetic-weight fallback, so this step is
# optional for development.
artifacts:
	cd python && python -m compile.aot --out ../artifacts

clean:
	cargo clean
	rm -rf artifacts

//! Fault-injection and degradation-ladder tests (ISSUE 7's acceptance
//! suite): deterministic, sleep-free, Gate-synchronised — the style of
//! `rust/tests/shard.rs`.
//!
//! The contract under test, in order:
//!
//! 1. **Zero-cost seam**: with no `FaultPlan` and no canary configured,
//!    served predictions, RNG streams, response JSON, and the `/metrics`
//!    payload are bitwise/textually identical to a build without the
//!    faults subsystem.
//! 2. **Demotion + recovery**: an injected drift event drops the canary
//!    accuracy below threshold; the shard publishes `Reprogramming`
//!    (gate-observable), re-programs the array (energy charged), verifies,
//!    and promotes back to `Healthy`.
//! 3. **Demotion + failure**: sticky stuck-at cells survive the re-program,
//!    the verify probe fails, and the shard lands in `DigitalFallback` —
//!    still serving correct (digital-reference) answers while `/healthz`
//!    reports degraded.
//! 4. **Deadlines**: a queue-expired `deadline_ms` fails fast with
//!    `DEADLINE_EXCEEDED` and leaves the gauges exactly zero.

use std::sync::Arc;

use hec::api::{ClassifyRequest, ErrorCode};
use hec::config::{Backend, Engine, RoutePolicy, ServeConfig};
use hec::coordinator::shard::{Gate, ShardHooks};
use hec::coordinator::{ClassifySurface, Pipeline, ShardSet};
use hec::dataset::SyntheticDataset;
use hec::faults::BackendState;

/// An artifacts directory that never exists -> synthetic fallback.
const NO_ARTIFACTS: &str = "/nonexistent-hec-artifacts";

fn cfg(backend: Backend, shards: usize) -> ServeConfig {
    let mut c = ServeConfig {
        artifacts_dir: NO_ARTIFACTS.into(),
        backend,
        engine: Engine::Interp,
        ..Default::default()
    };
    c.batch.max_batch = 1; // serial submits -> singleton batches, no timing
    c.batch.max_wait_us = 0;
    c.shards.count = shards;
    c.shards.policy = RoutePolicy::RoundRobin;
    c
}

fn workload(n: usize, seed: u64) -> (Vec<f32>, usize) {
    let meta = hec::runtime::Meta::synthetic();
    let ds = SyntheticDataset::new(seed, n, meta.norm.mean as f32, meta.norm.std as f32);
    let (images, _) = ds.batch(0, n);
    let s = meta.artifacts.image_size;
    (images, s * s)
}

// ---------------------------------------------------------------------------
// 1. Zero-cost-when-disabled seam
// ---------------------------------------------------------------------------

/// Faults off (no plan, no canary): an ACAM shard set with full device
/// variability serves bitwise-identically to an independent pipeline — the
/// faults subsystem consumed no RNG draw, ran no probe, touched nothing.
#[test]
fn faults_off_is_bitwise_identical_to_plain_serving() {
    let requests = 10;
    let mut c = cfg(Backend::AcamSim, 1);
    c.acam.variability_level = 1.0; // exercise programming + read noise RNG
    let (images, img_len) = workload(requests, 909_091);
    let set = ShardSet::start(&c).unwrap();
    let mut got = Vec::new();
    for i in 0..requests {
        let resp = set
            .handle
            .classify_blocking(images[i * img_len..(i + 1) * img_len].to_vec())
            .unwrap();
        // The additive v1 fields stay unset -> the encoded wire form
        // carries no trace of the ladder.
        assert_eq!(resp.degraded, None);
        assert_eq!(resp.backend_state, None);
        let json = resp.to_value().to_json();
        assert!(!json.contains("degraded"), "ladder leaked into: {json}");
        assert!(!json.contains("backend_state"), "ladder leaked into: {json}");
        got.push((resp.predictions[0].class, resp.predictions[0].score));
    }

    // No ladder series in /metrics, no backend_state key in health.
    let text = set.handle.prometheus_text();
    for absent in [
        "hec_shard_backend_state",
        "hec_canary_accuracy",
        "hec_reprogram_total",
    ] {
        assert!(!text.contains(absent), "{absent} leaked into:\n{text}");
    }
    assert!(set.handle.shard_ladder().is_none());
    let health = set.handle.health();
    assert!(!health.degraded);
    assert_eq!(health.shards[0].backend_state, None);
    set.shutdown();

    // Bitwise parity with a plain pipeline fed the same sequence: the RNG
    // stream position after each request must be untouched by the (inert)
    // fault machinery.
    let mut p = Pipeline::new(&c).unwrap();
    for (i, &(class, score)) in got.iter().enumerate() {
        let want = p
            .classify_batch(&images[i * img_len..(i + 1) * img_len], 1)
            .unwrap()
            .remove(0);
        assert_eq!(
            (class, score),
            (want.top1().class, want.top1().score),
            "request {i}: faults-off serving diverged from a plain pipeline"
        );
    }
}

// ---------------------------------------------------------------------------
// 2. Drift -> demote -> re-program -> promote
// ---------------------------------------------------------------------------

/// A drift event ages the array until the canary probe fails; the shard
/// walks `Healthy -> Reprogramming -> Healthy`: the intermediate state is
/// observable through the gate, the re-program is charged to the energy
/// ledger and counted in `hec_reprogram_total`, and the verify probe
/// (ideal re-programmed devices) restores full canary accuracy.
#[test]
fn drift_demotes_then_reprogram_recovers() {
    let canary_gate = Gate::new();
    let reprogram_gate = Gate::new();
    let mut c = cfg(Backend::AcamSim, 1);
    // Severe drift after 2 served requests; probe every 4.
    c.faults.plan = Some("drift@2=1000".into());
    c.faults.canary_every = 4;
    let (images, img_len) = workload(12, 616_161);
    let img = |i: usize| images[i * img_len..(i + 1) * img_len].to_vec();
    let set = ShardSet::start_with_hooks(
        &c,
        ShardHooks {
            canary_gate: Some(Arc::clone(&canary_gate)),
            reprogram_gate: Some(Arc::clone(&reprogram_gate)),
            ..Default::default()
        },
    )
    .unwrap();

    // Ladder surfaces are live from the start: healthy, no probe yet (NaN).
    let ladder = set.handle.shard_ladder().expect("ladder armed");
    assert_eq!(ladder[0].0, BackendState::Healthy);
    assert!(ladder[0].1.is_nan(), "accuracy must be NaN before any probe");
    assert_eq!(set.handle.health().shards[0].backend_state, Some("healthy"));
    let text = set.handle.prometheus_text();
    assert!(text.contains("hec_canary_accuracy{shard=\"0\"} NaN"), "{text}");

    // Requests 1-2 serve pre-drift; the event fires before request 3's
    // batch; the probe runs after request 4 and demotes the shard, parking
    // the worker on the reprogram gate with `Reprogramming` published.
    for i in 0..3 {
        let resp = set.handle.classify_blocking(img(i)).unwrap();
        assert_eq!(resp.degraded, Some(false));
        assert_eq!(resp.backend_state.as_deref(), Some("healthy"));
    }
    let fourth = set.handle.submit(ClassifyRequest::new(img(3))).unwrap();
    reprogram_gate.await_arrivals(1);
    assert_eq!(canary_gate.arrivals(), 1, "exactly one probe so far");
    // Request 4 itself dispatched while still Healthy...
    assert_eq!(
        fourth.recv().unwrap().unwrap().backend_state.as_deref(),
        Some("healthy")
    );
    // ...but the probe it triggered has published the demotion.
    let ladder = set.handle.shard_ladder().unwrap();
    assert_eq!(ladder[0].0, BackendState::Reprogramming);
    assert!(ladder[0].1 < 0.9, "drifted canary accuracy: {}", ladder[0].1);
    let health = set.handle.health();
    assert!(health.degraded, "reprogramming shard must degrade /healthz");
    assert!(health.shards[0].healthy, "worker itself is fine");
    assert_eq!(health.shards[0].backend_state, Some("reprogramming"));
    let text = set.handle.prometheus_text();
    assert!(text.contains("hec_shard_backend_state{shard=\"0\"} 1"), "{text}");

    let energy_before = set.handle.shard_metrics(0).energy_nj();

    // Release: re-program (fresh seed, baseline corner), verify on ideal
    // devices -> accuracy 1.0 -> promote.  Request 5 observes the recovery.
    reprogram_gate.release();
    let resp = set.handle.classify_blocking(img(4)).unwrap();
    assert_eq!(resp.degraded, Some(false));
    assert_eq!(resp.backend_state.as_deref(), Some("healthy"));
    let ladder = set.handle.shard_ladder().unwrap();
    assert_eq!(ladder[0].0, BackendState::Healthy);
    assert_eq!(ladder[0].1, 1.0, "ideal re-programmed array must verify clean");
    assert_eq!(ladder[0].2, 1, "one completed re-program");
    assert!(!set.handle.health().degraded);

    // The re-programming energy (plus the verify probe) hit the ledger.
    let p = Pipeline::new(&c).unwrap();
    let s = p.store.set(1).unwrap();
    let reprogram_nj = hec::energy::EnergyModel::default()
        .reprogram_nj(s.num_templates() as u64, s.num_features() as u64);
    let energy_after = set.handle.shard_metrics(0).energy_nj();
    assert!(
        energy_after - energy_before >= reprogram_nj,
        "re-program energy not charged: before {energy_before}, after {energy_after}, \
         expected at least +{reprogram_nj}"
    );
    let text = set.handle.prometheus_text();
    assert!(text.contains("hec_reprogram_total{shard=\"0\"} 1"), "{text}");
    assert!(text.contains("hec_canary_accuracy{shard=\"0\"} 1"), "{text}");

    // Next probe (after request 8) scores the healthy array clean.
    for i in 5..8 {
        set.handle.classify_blocking(img(i)).unwrap();
    }
    canary_gate.await_arrivals(2);
    let ladder = set.handle.shard_ladder().unwrap();
    assert_eq!(ladder[0].0, BackendState::Healthy);
    assert_eq!(ladder[0].1, 1.0);

    // Gauges exact after the whole episode.
    let snap = set.handle.shard_metrics(0).snapshot();
    assert_eq!(snap.queue_depth, 0);
    assert_eq!(snap.in_flight, 0);
    set.shutdown();
}

// ---------------------------------------------------------------------------
// 3. Stuck cells -> re-program fails -> DigitalFallback
// ---------------------------------------------------------------------------

/// Stuck-at cells are sticky: the re-program cannot heal them, the verify
/// probe fails, and the shard lands in `DigitalFallback` — `/healthz`
/// degraded, requests still succeeding with answers bitwise-equal to the
/// digital reference, and no further probes burned on a dead array.
#[test]
fn stuck_cells_survive_reprogram_and_land_in_digital_fallback() {
    let canary_gate = Gate::new();
    let mut c = cfg(Backend::AcamSim, 1);
    // Every cell stuck at G_MIN after 2 served requests; probe every 4.
    c.faults.plan = Some("stuck@2=1.0".into());
    c.faults.canary_every = 4;
    let (images, img_len) = workload(12, 323_232);
    let img = |i: usize| images[i * img_len..(i + 1) * img_len].to_vec();
    let set = ShardSet::start_with_hooks(
        &c,
        ShardHooks {
            canary_gate: Some(Arc::clone(&canary_gate)),
            ..Default::default()
        },
    )
    .unwrap();

    // Serve through the probe: demote -> re-program -> sticky re-applied ->
    // verify fails -> DigitalFallback, all before request 5 is served.
    for i in 0..5 {
        set.handle.classify_blocking(img(i)).unwrap();
    }
    let ladder = set.handle.shard_ladder().unwrap();
    assert_eq!(ladder[0].0, BackendState::DigitalFallback);
    assert!(ladder[0].1 < 0.9, "verify accuracy: {}", ladder[0].1);
    assert_eq!(ladder[0].2, 1, "the one failed re-program attempt");
    let health = set.handle.health();
    assert!(health.degraded);
    assert!(health.shards[0].healthy, "fallback is not a dead worker");
    assert_eq!(health.shards[0].backend_state, Some("digital_fallback"));
    let text = set.handle.prometheus_text();
    assert!(text.contains("hec_shard_backend_state{shard=\"0\"} 2"), "{text}");
    assert!(text.contains("hec_reprogram_total{shard=\"0\"} 1"), "{text}");

    // Requests keep succeeding, flagged degraded, and the answers are
    // bitwise the digital Eq. 8 reference (same store, same energy
    // envelope as a FeatureCount deployment).
    let mut reference = Pipeline::new(&cfg(Backend::FeatureCount, 1)).unwrap();
    let probes_before = canary_gate.arrivals();
    for i in 5..10 {
        let resp = set.handle.classify_blocking(img(i)).unwrap();
        assert_eq!(resp.degraded, Some(true));
        assert_eq!(resp.backend_state.as_deref(), Some("digital_fallback"));
        let want = reference
            .classify_batch(&images[i * img_len..(i + 1) * img_len], 1)
            .unwrap()
            .remove(0);
        assert_eq!(resp.predictions[0].class, want.top1().class);
        assert_eq!(resp.predictions[0].score, want.top1().score);
        assert_eq!(resp.energy.back_end_nj, want.energy.back_end_nj);
    }
    assert_eq!(
        canary_gate.arrivals(),
        probes_before,
        "DigitalFallback must stop burning canary probes"
    );
    set.shutdown();
}

/// A panic-restart rebuilds a clean array and resets the ladder to
/// `Healthy` — the restart is the operator's escape hatch from
/// `DigitalFallback` without bouncing the deployment.
#[test]
fn restart_resets_the_ladder_from_digital_fallback() {
    let restart_gate = Gate::new();
    let mut c = cfg(Backend::AcamSim, 1);
    c.faults.plan = Some("stuck@1=1.0".into());
    c.faults.canary_every = 2;
    let (images, img_len) = workload(8, 747_474);
    let img = |i: usize| images[i * img_len..(i + 1) * img_len].to_vec();
    let set = ShardSet::start_with_hooks(
        &c,
        ShardHooks {
            panic_on: Some("boom".into()),
            restart_gate: Some(Arc::clone(&restart_gate)),
            ..Default::default()
        },
    )
    .unwrap();

    // Drive into DigitalFallback (stuck fires before request 2, probe
    // after request 2 fails, re-program + verify fails).
    for i in 0..3 {
        set.handle.classify_blocking(img(i)).unwrap();
    }
    assert_eq!(
        set.handle.shard_ladder().unwrap()[0].0,
        BackendState::DigitalFallback
    );

    // Panic the worker; the restart rebuilds pipeline + canary set and
    // returns the ladder to Healthy.
    let mut req = ClassifyRequest::new(img(3));
    req.request_id = Some("boom".into());
    assert_eq!(
        set.handle.submit_blocking(req).err().map(|e| e.code),
        Some(ErrorCode::Internal)
    );
    restart_gate.await_arrivals(1);
    restart_gate.release();
    restart_gate.await_arrivals(2);
    assert_eq!(
        set.handle.shard_ladder().unwrap()[0].0,
        BackendState::Healthy,
        "restart must reset the ladder (clean array)"
    );
    assert!(!set.handle.health().degraded);
    set.shutdown();
}

// ---------------------------------------------------------------------------
// 4. Per-request deadlines
// ---------------------------------------------------------------------------

/// A job whose `deadline_ms` expired in the queue fails fast with
/// `DEADLINE_EXCEEDED` before compute, and the PR 4 drain discipline
/// holds: `queue_depth`/`in_flight` return to exactly zero.
#[test]
fn queue_expired_deadline_fails_fast_and_zeroes_gauges() {
    let hold_gate = Gate::new();
    let c = {
        let mut c = cfg(Backend::FeatureCount, 1);
        c.batch.queue_depth = 8;
        c
    };
    let (images, img_len) = workload(1, 111_213);
    let img = images[..img_len].to_vec();
    let set = ShardSet::start_with_hooks(
        &c,
        ShardHooks {
            hold: Some(("hold".into(), Arc::clone(&hold_gate))),
            ..Default::default()
        },
    )
    .unwrap();

    // Park the worker, then queue one already-expired job (`deadline_ms:
    // 0` expires by definition — the deterministic probe) and one without
    // a deadline behind it.
    let mut req = ClassifyRequest::new(img.clone());
    req.request_id = Some("hold".into());
    let hold_rx = set.handle.submit(req).unwrap();
    hold_gate.await_arrivals(1);
    let mut expired = ClassifyRequest::new(img.clone());
    expired.deadline_ms = Some(0);
    let expired_rx = set.handle.submit(expired).unwrap();
    let mut patient = ClassifyRequest::new(img.clone());
    patient.deadline_ms = Some(u64::MAX / 2);
    let patient_rx = set.handle.submit(patient).unwrap();

    hold_gate.release();
    assert!(hold_rx.recv().unwrap().is_ok());
    let err = expired_rx.recv().unwrap().err().expect("must expire");
    assert_eq!(err.code, ErrorCode::DeadlineExceeded);
    assert_eq!(err.code.as_str(), "DEADLINE_EXCEEDED");
    assert!(err.message.contains("deadline"), "{}", err.message);
    // The un-expired deadline job behind it computes normally.
    assert!(patient_rx.recv().unwrap().is_ok());

    // Accounting: the expired job is an error, not a response, and every
    // gauge is exactly zero once the waiters resolved.
    let snap = set.handle.shard_metrics(0).snapshot();
    assert_eq!(snap.queue_depth, 0, "queue_depth leaked past the drop");
    assert_eq!(snap.in_flight, 0, "in_flight leaked past the drop");
    assert_eq!(snap.responses, 2);
    assert_eq!(snap.errors, 1);
    set.shutdown();
}

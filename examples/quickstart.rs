//! Quickstart: classify a handful of samples through the full hybrid stack
//! and print predictions + the per-inference energy estimate.
//!
//! Runs on a clean checkout with **no artifacts directory**: the default
//! interp engine falls back to synthetic weights and bootstraps templates
//! from the synthetic dataset.  With `make artifacts` it picks up the real
//! exported weights instead.
//!
//!     cargo run --release --example quickstart

use hec::config::{Backend, ServeConfig};
use hec::coordinator::Pipeline;
use hec::dataset::{SyntheticDataset, CLASS_NAMES};

fn main() -> hec::Result<()> {
    // 1. Point the pipeline at the artifacts directory (used when present,
    //    synthetic fallback otherwise).
    let cfg = ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::AcamSim, // the paper's system: CNN front-end + ACAM
        templates_per_class: 1,
        ..Default::default()
    };
    let mut pipeline = Pipeline::new(&cfg)?;
    println!(
        "loaded: engine {}, {} features, {} templates, image {}x{} (dataset: {})",
        pipeline.engine_name(),
        pipeline.meta.artifacts.n_features,
        pipeline.meta.artifacts.n_templates,
        pipeline.meta.artifacts.image_size,
        pipeline.meta.artifacts.image_size,
        pipeline.meta.dataset.source,
    );

    // 2. Build a small labelled workload (the synthetic CIFAR-like test
    //    distribution the models were trained against).
    let n = 12;
    let ds = SyntheticDataset::new(
        1_000_003,
        n,
        pipeline.meta.norm.mean as f32,
        pipeline.meta.norm.std as f32,
    );
    let (images, labels) = ds.batch(0, n);

    // 3. Classify.
    let results = pipeline.classify_batch(&images, n)?;
    let mut correct = 0;
    for (i, r) in results.iter().enumerate() {
        let top = r.top1();
        let ok = top.class == labels[i];
        correct += usize::from(ok);
        println!(
            "sample {i:>2}: {} -> predicted {:<10} truth {:<10} ({:.2} nJ = front {:.2} + back {:.2})",
            if ok { "ok " } else { "ERR" },
            CLASS_NAMES[top.class],
            CLASS_NAMES[labels[i]],
            r.energy.total_nj(),
            r.energy.front_end_nj,
            r.energy.back_end_nj,
        );
    }
    println!("\naccuracy {correct}/{n}");

    // 4. The §V.D energy story for this deployment.
    println!("\n{}", pipeline.energy_report());
    Ok(())
}

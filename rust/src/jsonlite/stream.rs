//! Pull-parser (streaming) mode for [`crate::jsonlite`] — the zero-tree
//! ingestion path (SNIPPETS ADR-002: lazy scanning beats tree parsing ~33x
//! for partial extraction; the gateway reads three small fields and one huge
//! number array per request, the worst case for a tree).
//!
//! [`PullParser`] scans a document left to right and hands the caller one
//! token at a time: callers drive objects with [`PullParser::next_key`],
//! arrays with [`PullParser::next_element`], and read or skip each value in
//! place — no [`super::Value`] tree, no `BTreeMap`, no per-number enum
//! allocation.  Bulk number arrays decode straight into a caller-owned
//! `Vec<f32>` buffer.
//!
//! **Parity contract** (enforced by `rust/tests/ingest_fuzz.rs`): for every
//! input, the pull parser accepts exactly the documents [`super::parse`]
//! accepts, rejects with the *same [`super::ParseError`] message at the same
//! byte offset*, and produces bitwise-identical numbers.  The grammar is
//! deliberately a mirror of the tree parser's, quirks included (lenient
//! leading zeros, `"5."`-style numbers, `\u` escapes validated through
//! `u32::from_str_radix`); any divergence is a bug in this module, not a
//! feature.  Numbers go through a Clinger-style fast path (exact `u64`
//! mantissa × exact power of ten — correctly rounded by construction, so
//! bit-identical to `str::parse::<f64>`) and fall back to `str::parse` for
//! anything outside the provably-exact class.

use super::ParseError;

/// Powers of ten exactly representable in f64 (10^0 ..= 10^22).  10^23 is
/// the first inexact one, so 22 bounds the Clinger fast path.
const POW10: [f64; 23] = [
    1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10, 1e11, 1e12, 1e13, 1e14, 1e15, 1e16,
    1e17, 1e18, 1e19, 1e20, 1e21, 1e22,
];

/// What the next value in the stream is, classified from its first byte
/// (the same dispatch the tree parser's `value()` does).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Object,
    Array,
    Str,
    Num,
    Bool,
    Null,
}

/// A streaming JSON scanner over a borrowed document.
pub struct PullParser<'a> {
    text: &'a str,
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> PullParser<'a> {
    pub fn new(text: &'a str) -> Self {
        PullParser {
            text,
            bytes: text.as_bytes(),
            pos: 0,
        }
    }

    /// Current byte offset (for error reporting / resynchronisation).
    pub fn pos(&self) -> usize {
        self.pos
    }

    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    pub fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    /// Classify the value at the cursor without consuming it.  Callers must
    /// be positioned at a value start (the object/array protocols guarantee
    /// this).  `Err` carries the tree parser's "expected a JSON value".
    pub fn peek_kind(&self) -> Result<Kind, ParseError> {
        match self.peek() {
            Some(b'{') => Ok(Kind::Object),
            Some(b'[') => Ok(Kind::Array),
            Some(b'"') => Ok(Kind::Str),
            Some(b't') | Some(b'f') => Ok(Kind::Bool),
            Some(b'n') => Ok(Kind::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => Ok(Kind::Num),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    // ---- object / array protocols ---------------------------------------

    /// Consume the opening `{` of an object.
    pub fn begin_object(&mut self) -> Result<(), ParseError> {
        self.expect(b'{')
    }

    /// Advance to the next key of the object being scanned.  `first` is a
    /// caller-owned flag, `true` before the first call; the parser leaves
    /// the cursor on the key's value (whitespace skipped).  Returns `None`
    /// once `}` is consumed.
    pub fn next_key(&mut self, first: &mut bool) -> Result<Option<String>, ParseError> {
        if *first {
            *first = false;
            self.skip_ws();
            if self.peek() == Some(b'}') {
                self.pos += 1;
                return Ok(None);
            }
        } else {
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(None);
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
        self.skip_ws();
        let key = self.read_string_body()?;
        self.skip_ws();
        self.expect(b':')?;
        self.skip_ws();
        Ok(Some(key))
    }

    /// Consume the opening `[` of an array.
    pub fn begin_array(&mut self) -> Result<(), ParseError> {
        self.expect(b'[')
    }

    /// Advance to the next element of the array being scanned (same
    /// caller-owned `first` flag protocol as [`PullParser::next_key`]).
    /// Returns `false` once `]` is consumed; on `true` the cursor sits on
    /// the element value.
    pub fn next_element(&mut self, first: &mut bool) -> Result<bool, ParseError> {
        if *first {
            *first = false;
            self.skip_ws();
            if self.peek() == Some(b']') {
                self.pos += 1;
                return Ok(false);
            }
        } else {
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(false);
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
        self.skip_ws();
        Ok(true)
    }

    // ---- scalar readers --------------------------------------------------

    /// Read a string value (cursor on the opening quote).
    pub fn read_string(&mut self) -> Result<String, ParseError> {
        self.read_string_body()
    }

    fn read_string_body(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    self.escape(Some(&mut s))?;
                    self.pos += 1;
                }
                Some(c) if c < 0x80 => {
                    s.push(c as char);
                    self.pos += 1;
                }
                Some(_) => {
                    // Multi-byte scalar: the cursor only ever rests on char
                    // boundaries, so this lookup cannot fail.
                    let c = self.text[self.pos..].chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    /// Skip a string without building it (same validation, same errors).
    fn skip_string(&mut self) -> Result<(), ParseError> {
        self.expect(b'"')?;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(());
                }
                Some(b'\\') => {
                    self.pos += 1;
                    self.escape(None)?;
                    self.pos += 1;
                }
                Some(_) => {
                    // Content bytes are skipped bytewise: UTF-8 continuation
                    // bytes can never equal the ASCII quote or backslash.
                    self.pos += 1;
                }
            }
        }
    }

    /// Validate (and optionally decode into `out`) one escape sequence; the
    /// cursor sits on the escape character after the backslash.  Mirrors the
    /// tree parser byte for byte, including validating `\u` hex through
    /// `u32::from_str_radix` and mapping unpaired surrogates to U+FFFD.
    fn escape(&mut self, out: Option<&mut String>) -> Result<(), ParseError> {
        let c = match self.peek() {
            Some(b'"') => '"',
            Some(b'\\') => '\\',
            Some(b'/') => '/',
            Some(b'n') => '\n',
            Some(b't') => '\t',
            Some(b'r') => '\r',
            Some(b'b') => '\u{8}',
            Some(b'f') => '\u{c}',
            Some(b'u') => {
                if self.pos + 4 >= self.bytes.len() {
                    return Err(self.err("bad \\u escape"));
                }
                let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                    .map_err(|_| self.err("bad \\u escape"))?;
                let cp =
                    u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                self.pos += 4;
                char::from_u32(cp).unwrap_or('\u{fffd}')
            }
            _ => return Err(self.err("bad escape")),
        };
        if let Some(s) = out {
            s.push(c);
        }
        Ok(())
    }

    /// Read a number value (cursor on `-` or a digit) as f64 —
    /// bit-identical to the tree parser's `str::parse::<f64>` on the same
    /// lexeme, via the Clinger fast path where provably exact.
    pub fn read_f64(&mut self) -> Result<f64, ParseError> {
        let lex = self.lex_number()?;
        // Fast path: value is mantissa * 10^k with both factors exactly
        // representable, so one IEEE multiply/divide is correctly rounded —
        // identical to what a full correctly-rounding parser returns.
        if let Some(f) = lex.fast_value() {
            return Ok(f);
        }
        self.text[lex.start..lex.end]
            .parse::<f64>()
            .map_err(|_| self.err("bad number"))
    }

    /// Skip a number (cursor on `-` or a digit), applying the same validity
    /// rule the tree parser's `str::parse` does.
    fn skip_number(&mut self) -> Result<(), ParseError> {
        self.lex_number().map(|_| ())
    }

    /// Lex one number lexeme with the tree parser's exact character
    /// classes, rejecting (at the tree parser's position, with its message)
    /// lexemes `str::parse::<f64>` would reject.
    fn lex_number(&mut self) -> Result<NumLex, ParseError> {
        let start = self.pos;
        let mut neg = false;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            neg = true;
        }
        // Exact-u64 mantissa accumulation; `exact` goes false once the
        // mantissa needs more than 15 significant digits (2^53 safety) and
        // the slow path takes over for the value (the lexing continues).
        let mut mant: u64 = 0;
        let mut mant_digits: u32 = 0;
        let mut exact = true;
        let mut int_digits = 0usize;
        while let Some(c) = self.peek() {
            if !c.is_ascii_digit() {
                break;
            }
            let d = (c - b'0') as u64;
            if mant == 0 && d == 0 {
                // Leading zeros: value-neutral, not significant digits.
            } else if mant_digits < 15 {
                mant = mant * 10 + d;
                mant_digits += 1;
            } else {
                exact = false;
            }
            int_digits += 1;
            self.pos += 1;
        }
        let mut frac_digits = 0usize;
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while let Some(c) = self.peek() {
                if !c.is_ascii_digit() {
                    break;
                }
                let d = (c - b'0') as u64;
                if mant == 0 && d == 0 {
                    // Still value-neutral, but the decimal exponent below
                    // accounts for the position via `frac_digits`.
                } else if mant_digits < 15 {
                    mant = mant * 10 + d;
                    mant_digits += 1;
                } else {
                    exact = false;
                }
                frac_digits += 1;
                self.pos += 1;
            }
        }
        let mut exp: i64 = 0;
        let mut exp_present = false;
        let mut exp_digits = 0usize;
        if matches!(self.peek(), Some(b'e' | b'E')) {
            exp_present = true;
            self.pos += 1;
            let mut exp_neg = false;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                exp_neg = self.peek() == Some(b'-');
                self.pos += 1;
            }
            while let Some(c) = self.peek() {
                if !c.is_ascii_digit() {
                    break;
                }
                exp = (exp * 10 + (c - b'0') as i64).min(1_000_000);
                exp_digits += 1;
                self.pos += 1;
            }
            if exp_neg {
                exp = -exp;
            }
        }
        // `str::parse::<f64>` acceptance, restated for this lexeme grammar:
        // at least one digit overall, and a non-empty exponent when the
        // marker is present.
        if int_digits + frac_digits == 0 || (exp_present && exp_digits == 0) {
            return Err(self.err("bad number"));
        }
        Ok(NumLex {
            start,
            end: self.pos,
            neg,
            mant,
            exact,
            k: exp - frac_digits as i64,
        })
    }

    // ---- whole-value / document helpers ---------------------------------

    /// Validate-and-discard one complete value (cursor at its start).  The
    /// whole subtree gets the same syntax validation the tree parser
    /// applies, so "skipped" never means "unchecked".
    pub fn skip_value(&mut self) -> Result<(), ParseError> {
        match self.peek_kind()? {
            Kind::Object => {
                self.begin_object()?;
                let mut first = true;
                while self.next_key(&mut first)?.is_some() {
                    self.skip_value()?;
                }
                Ok(())
            }
            Kind::Array => {
                self.begin_array()?;
                let mut first = true;
                while self.next_element(&mut first)? {
                    self.skip_value()?;
                }
                Ok(())
            }
            Kind::Str => self.skip_string(),
            Kind::Num => self.skip_number(),
            Kind::Bool | Kind::Null => {
                let word = match self.peek() {
                    Some(b't') => "true",
                    Some(b'f') => "false",
                    _ => "null",
                };
                self.literal(word)
            }
        }
    }

    /// Consume a literal keyword (`true` / `false` / `null`) with the tree
    /// parser's message on mismatch.
    pub fn literal(&mut self, word: &str) -> Result<(), ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    /// Read a boolean (cursor on `t` or `f`).
    pub fn read_bool(&mut self) -> Result<bool, ParseError> {
        if self.peek() == Some(b't') {
            self.literal("true")?;
            Ok(true)
        } else {
            self.literal("false")?;
            Ok(false)
        }
    }

    /// After the top-level value: require end of input (the tree parser's
    /// trailing-characters check).
    pub fn end(&mut self) -> Result<(), ParseError> {
        self.skip_ws();
        if self.pos != self.bytes.len() {
            return Err(self.err("trailing characters"));
        }
        Ok(())
    }
}

/// One lexed number: the slice bounds for the slow path plus the exact
/// mantissa/exponent decomposition for the fast path.
struct NumLex {
    start: usize,
    end: usize,
    neg: bool,
    mant: u64,
    exact: bool,
    /// Decimal exponent applied to `mant` (explicit exponent minus
    /// fraction length).
    k: i64,
}

impl NumLex {
    /// The Clinger fast path: when the mantissa fits in 53 bits and the
    /// scale is an exact power of ten, one IEEE op on exact operands is
    /// correctly rounded — the same result every correctly-rounding parser
    /// (including `str::parse`) must return.  `None` defers to `str::parse`.
    fn fast_value(&self) -> Option<f64> {
        if !self.exact || self.k.unsigned_abs() > 22 {
            return None;
        }
        let mut f = self.mant as f64;
        if self.k > 0 {
            f *= POW10[self.k as usize];
        } else if self.k < 0 {
            f /= POW10[(-self.k) as usize];
        }
        Some(if self.neg { -f } else { f })
    }
}

#[cfg(test)]
mod tests {
    use super::super::{parse, Value};
    use super::*;

    /// Full-document scan via the pull API: skip the top value, require end.
    fn scan(text: &str) -> Result<(), ParseError> {
        let mut p = PullParser::new(text);
        p.skip_ws();
        p.skip_value()?;
        p.end()
    }

    /// The core parity assertion: accept/reject, message, and byte offset
    /// all match the tree parser.
    fn assert_parity(text: &str) {
        let tree = parse(text);
        let stream = scan(text);
        match (tree, stream) {
            (Ok(_), Ok(())) => {}
            (Err(t), Err(s)) => {
                assert_eq!(t.msg, s.msg, "message parity on {text:?}");
                assert_eq!(t.pos, s.pos, "position parity on {text:?}");
            }
            (t, s) => panic!("accept parity on {text:?}: tree {t:?} vs stream {s:?}"),
        }
    }

    #[test]
    fn parity_on_valid_documents() {
        for text in [
            "null",
            "true",
            "false",
            "0",
            "-0",
            "-3.5e2",
            "5.",
            "-.5",
            "01",
            "1200e-2",
            "1e999",
            "\"hi\\n\\u0041\\u00e9 caf\u{e9}\"",
            "[]",
            "[1, 2.5, [3], {\"a\": null}]",
            "{}",
            r#"{"a": [1, 2, {"b": "x"}], "c": null}"#,
            "  {\"k\"\t:\r\n [true]}  ",
            "\"\\u+12f\"", // from_str_radix quirk: leading '+' accepted
            "\"\\ud800\"", // unpaired surrogate -> U+FFFD in both parsers
        ] {
            assert_parity(text);
        }
    }

    #[test]
    fn parity_on_invalid_documents() {
        for text in [
            "",
            "   ",
            "{",
            "[",
            "[1,]",
            "[,1]",
            "{\"a\" 1}",
            "{\"a\":}",
            "{,}",
            "[1 2]",
            "1 2",
            "nul",
            "truex trailing",
            "\"unterminated",
            "\"bad \\q escape\"",
            "\"\\u12",
            "\"\\u12g4\"",
            "-",
            "-.",
            "1e",
            "1e+",
            "1.e",
            "[5..5]",
            "{\"dup\": 1, \"dup\": }",
        ] {
            assert_parity(text);
        }
    }

    #[test]
    fn numbers_bitwise_match_str_parse() {
        for text in [
            "0",
            "-0",
            "-0.0",
            "1",
            "0.1",
            "0.1307",
            "-0.3081",
            "5.",
            "-.5",
            "0005.500",
            "1200e-2",
            "9007199254740991",  // 2^53 - 1: still exact
            "900719925474099123", // 18 digits: past the fast path
            "1.7976931348623157e308",
            "5e-324",
            "2.2250738585072014e-308",
            "123456789.123456789",
            "1e22",
            "1e23",
            "-1e-22",
            "3.141592653589793",
            "1e999", // overflow -> inf in both
        ] {
            let mut p = PullParser::new(text);
            let got = p.read_f64().unwrap();
            let want: f64 = text.parse().unwrap();
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "{text}: stream {got:e} vs parse {want:e}"
            );
        }
    }

    #[test]
    fn pull_protocol_reads_fields() {
        let mut p = PullParser::new(r#"{"a": 1.5, "b": [1, 2], "s": "x", "t": true}"#);
        p.skip_ws();
        p.begin_object().unwrap();
        let mut first = true;
        let mut seen = Vec::new();
        while let Some(key) = p.next_key(&mut first).unwrap() {
            match key.as_str() {
                "a" => assert_eq!(p.read_f64().unwrap(), 1.5),
                "b" => {
                    let mut ef = true;
                    let mut vals = Vec::new();
                    p.begin_array().unwrap();
                    while p.next_element(&mut ef).unwrap() {
                        vals.push(p.read_f64().unwrap());
                    }
                    assert_eq!(vals, [1.0, 2.0]);
                }
                "s" => assert_eq!(p.read_string().unwrap(), "x"),
                "t" => assert!(p.read_bool().unwrap()),
                other => panic!("unexpected key {other}"),
            }
            seen.push(key);
        }
        p.end().unwrap();
        assert_eq!(seen, ["a", "b", "s", "t"]);
    }

    #[test]
    fn string_decoding_matches_tree() {
        let cases: [&str; 4] = [
            r#""plain""#,
            r#""a\nb\t\"q\" \\ \/ \b \f""#,
            r#""caf\u00e9 \u2603 \ud800""#,
            "\"raw caf\u{e9} \u{2603}\"",
        ];
        for text in cases {
            let want = match parse(text).unwrap() {
                Value::Str(s) => s,
                v => panic!("not a string: {v:?}"),
            };
            let mut p = PullParser::new(text);
            assert_eq!(p.read_string().unwrap(), want, "on {text:?}");
            assert_eq!(p.pos(), text.len(), "fully consumed {text:?}");
        }
    }
}

//! §V.D energy report: the paper-scale reproduction (published arithmetic),
//! the strict-pJ variant (unit-slip note in `hec::energy`), the as-built
//! deployment, and the per-layer Eq. 13 MAC ledger.
//!
//!     cargo run --release --example energy_report

use hec::energy::{constants, effective_macs, student_layers, EnergyModel, Scale};
use hec::runtime::Meta;

fn main() -> hec::Result<()> {
    let model = EnergyModel::default();

    println!("=== §V.D (paper scale, published arithmetic) ===");
    let r = model.report(Scale::Paper);
    println!("{r}");
    println!(
        "\npublished: E_front={} nJ  E_back={} nJ  E_total={} nJ  teacher={} uJ  reduction={}x",
        constants::E_FRONTEND_NJ,
        constants::E_BACKEND_NJ,
        constants::E_TOTAL_NJ,
        constants::E_TEACHER_UJ,
        constants::ENERGY_REDUCTION
    );
    println!(
        "strict-pJ front-end variant: {:.0} nJ (x1000 the published figure — \
         see the unit-slip note in rust/src/energy/mod.rs)",
        model.frontend_strict_pj_nj(constants::FRONTEND_OPS_ACAM)
    );

    println!("\n=== Eq. 13 ledger: Fig.-5 student, per layer ===");
    println!("{:<8} {:>14} {:>10}", "layer", "MACs", "params");
    let layers = student_layers();
    for l in &layers {
        println!("{:<8} {:>14} {:>10}", l.name(), l.macs(), l.params());
    }
    let total: u64 = layers.iter().map(|l| l.macs()).sum();
    println!("{:<8} {:>14}", "total", total);
    println!(
        "effective at 80% sparsity: {} (paper: {})",
        effective_macs(total, 0.8),
        constants::STUDENT_OPT.macs
    );

    if let Ok(meta) = Meta::load("artifacts") {
        println!("\n=== as-built deployment ===");
        println!(
            "{}",
            model.report(Scale::AsBuilt {
                frontend_ops: meta.macs.as_built.student_effective,
                teacher_macs: meta.macs.as_built.teacher_gray.macs,
                n_templates: meta.artifacts.n_templates as u64,
                n_features: meta.artifacts.n_features as u64,
            })
        );
        println!(
            "\n(as-built teacher is width-scaled for CPU training — the paper-scale \
             block above is the published comparison; see DESIGN.md §Substitutions)"
        );
    } else {
        println!("\n(no artifacts/ — run `make artifacts` for the as-built block)");
    }
    Ok(())
}

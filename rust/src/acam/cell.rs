//! TXL-ACAM pixel models — the two published cell designs (Fig. 4).
//!
//! Both cells store a matching window `[v_lo, v_hi]`: the input line voltage
//! matches when it falls inside the window.  The window bounds live in RRAM
//! conductances:
//!
//! * **6T4R charging cell** (Fig. 4a): two hybrid RRAM-CMOS inverters, each
//!   with a pull-up/pull-down RRAM pair whose ratio sets the inverter's
//!   switching threshold — `v_th = VDD * g_up / (g_up + g_down)`.  On a
//!   match, a current-limited pMOS *charges* the matchline; mismatching
//!   cells contribute nothing.  Preferred for sparse activations (most cells
//!   idle).
//! * **3T1R precharging cell** (Fig. 4b): a 1T1R voltage divider drives a
//!   complementary nMOS/pMOS pair hanging off dual matchlines
//!   (`ML_LOW`/`ML_HIGH`).  Input below the low bound *discharges* `ML_LOW`;
//!   input above the high bound discharges `ML_HIGH`; in-window inputs leave
//!   both precharged.  Smaller cell, and evaluating each bound separately
//!   makes the cell differentiable (trainable thresholds).
//!
//! The behavioural contract shared by both: `response(v_in)` reports whether
//! the cell matches and the current it pushes onto (or pulls off) its
//! matchline(s).


use super::rram::{RramDevice, G_MAX, G_MIN};
use super::variability::Variability;
use super::VDD;

/// Which TXL pixel the array is built from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellKind {
    /// Fig. 4a — 6T4R charging design.
    Charging6T4R,
    /// Fig. 4b — 3T1R precharging design.
    Precharging3T1R,
    /// 9T4R analogue cell (arxiv 2410.03414): same 4-RRAM window storage,
    /// but the richer 9-transistor periphery grades the output current with
    /// the input's distance from the window instead of switching hard.  A
    /// near-miss still charges the matchline a little, so the row voltage
    /// encodes analogue template distance, not just the Eq. 8 match count.
    Analogue9T4R,
}

/// Overdrive span (V) over which the 9T4R cell's charge current rolls off
/// linearly from `I_LIMIT` to zero as the input leaves the stored window.
/// Inputs further than this from either bound contribute nothing — a binary
/// query bit on the wrong side of the window (1 V away) is fully rejected,
/// which keeps the 9T4R array's ideal match counts equal to Eq. 8.
pub const V_ROLLOFF_9T4R: f64 = 0.25;


/// Current-limiter budget per cell (A).
///
/// Design point: with the default periphery (5 fF/cell matchline loading,
/// 20 ns evaluation window) a *full-row* match charges the matchline to
/// `I * t_eval / C_cell = 0.4 µA * 20 ns / 5 fF = 1.6 V` — deliberately
/// below VDD so the matchline never clamps and row voltage stays strictly
/// monotone in the number of matching cells (the property that makes the
/// analogue argmax equal Eq. 8 + Eq. 12).
pub const I_LIMIT: f64 = 0.4e-6;
/// Discharge current scale for the 3T1R cell (A); same design point, so a
/// full-row mismatch pulls a precharged line down by 1.6 V.
pub const I_DISCHARGE: f64 = 0.4e-6;

/// Convert a desired threshold voltage into an RRAM conductance pair.
///
/// `v_th = VDD * g_up / (g_up + g_dn)` fixes only the *ratio*
/// `r = g_up / g_dn = v_th / (VDD - v_th)`; splitting the ratio
/// geometrically around the mid-window conductance
/// (`g_up = g_mid * sqrt(r)`, `g_dn = g_mid / sqrt(r)`) keeps both devices
/// inside the `[G_MIN, G_MAX]` programming window across the full
/// representable ratio range `[G_MIN/G_MAX, G_MAX/G_MIN]` — i.e. thresholds
/// in `[~0.018, ~1.78] V`.
pub fn threshold_to_conductances(v_th: f64) -> (f64, f64) {
    let g_mid = (G_MIN * G_MAX).sqrt();
    let v = v_th.clamp(0.02, VDD - 0.02);
    let r = (v / (VDD - v)).clamp(G_MIN / G_MAX, G_MAX / G_MIN);
    let s = r.sqrt();
    ((g_mid * s).clamp(G_MIN, G_MAX), (g_mid / s).clamp(G_MIN, G_MAX))
}

/// Recover the threshold voltage implemented by a conductance pair.
pub fn conductances_to_threshold(g_up: f64, g_dn: f64) -> f64 {
    VDD * g_up / (g_up + g_dn)
}

/// One ACAM pixel: a `[lo, hi]` window in two RRAM pairs.
#[derive(Debug, Clone)]
pub struct AcamCell {
    pub kind: CellKind,
    lo_up: RramDevice,
    lo_dn: RramDevice,
    hi_up: RramDevice,
    hi_dn: RramDevice,
}

/// What a cell does to its matchline(s) during one evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellResponse {
    /// Whether the input fell inside the stored window.
    pub matched: bool,
    /// 6T4R: current pushed onto the (single) matchline on a match.
    pub i_charge: f64,
    /// 3T1R: current pulled off ML_LOW (input below window).
    pub i_dis_low: f64,
    /// 3T1R: current pulled off ML_HIGH (input above window).
    pub i_dis_high: f64,
}

impl AcamCell {
    /// Program a cell to the window `[v_lo, v_hi]` (volts) through the
    /// variability model.
    pub fn program(
        kind: CellKind,
        v_lo: f64,
        v_hi: f64,
        var: &Variability,
        rng: &mut crate::rng::Rng,
    ) -> Self {
        debug_assert!(v_lo <= v_hi, "window must satisfy lo <= hi");
        let (glo_up, glo_dn) = threshold_to_conductances(v_lo);
        let (ghi_up, ghi_dn) = threshold_to_conductances(v_hi);
        AcamCell {
            kind,
            lo_up: RramDevice::program(glo_up, var, rng),
            lo_dn: RramDevice::program(glo_dn, var, rng),
            hi_up: RramDevice::program(ghi_up, var, rng),
            hi_dn: RramDevice::program(ghi_dn, var, rng),
        }
    }

    /// Stuck-at fault: freeze all four RRAM devices at conductance `g`.
    ///
    /// With `g_up == g_dn` both inverter thresholds collapse to VDD/2, so
    /// the stored window degenerates to a point far from both binary query
    /// voltages — the cell stops matching either bit value.
    pub fn stick_at(&mut self, g: f64) {
        self.lo_up.force_conductance(g);
        self.lo_dn.force_conductance(g);
        self.hi_up.force_conductance(g);
        self.hi_dn.force_conductance(g);
    }

    /// The effective window at read time (after read noise / drift).
    pub fn window(&self, var: &Variability, rng: &mut crate::rng::Rng) -> (f64, f64) {
        let lo = conductances_to_threshold(
            self.lo_up.read(var, rng),
            self.lo_dn.read(var, rng),
        );
        let hi = conductances_to_threshold(
            self.hi_up.read(var, rng),
            self.hi_dn.read(var, rng),
        );
        (lo, hi.max(lo))
    }

    /// The programmed window without noise (diagnostics / calibration).
    pub fn nominal_window(&self) -> (f64, f64) {
        let lo = conductances_to_threshold(
            self.lo_up.conductance(),
            self.lo_dn.conductance(),
        );
        let hi = conductances_to_threshold(
            self.hi_up.conductance(),
            self.hi_dn.conductance(),
        );
        (lo, hi.max(lo))
    }

    /// Evaluate the cell against an input voltage.
    pub fn response(&self, v_in: f64, var: &Variability, rng: &mut crate::rng::Rng) -> CellResponse {
        let (lo, hi) = self.window(var, rng);
        let matched = v_in >= lo && v_in <= hi;
        match self.kind {
            CellKind::Charging6T4R => CellResponse {
                matched,
                i_charge: if matched { I_LIMIT } else { 0.0 },
                i_dis_low: 0.0,
                i_dis_high: 0.0,
            },
            CellKind::Analogue9T4R => {
                // Graded charging: full current inside the window, linear
                // roll-off with overdrive outside it (the analogue-distance
                // behaviour of the 9T4R periphery).
                let dist = (lo - v_in).max(0.0).max((v_in - hi).max(0.0));
                let scale = (1.0 - dist / V_ROLLOFF_9T4R).max(0.0);
                CellResponse {
                    matched,
                    i_charge: I_LIMIT * scale,
                    i_dis_low: 0.0,
                    i_dis_high: 0.0,
                }
            }
            CellKind::Precharging3T1R => {
                // Discharge strength grows with how far outside the window
                // the input sits (the MOS overdrive), saturating at I_DISCHARGE.
                let below = (lo - v_in).max(0.0);
                let above = (v_in - hi).max(0.0);
                let sat = |v: f64| I_DISCHARGE * (v / 0.2).min(1.0);
                CellResponse {
                    matched,
                    i_charge: 0.0,
                    i_dis_low: sat(below),
                    i_dis_high: sat(above),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
        
    fn rng() -> crate::rng::Rng {
        crate::rng::Rng::new(0)
    }

    #[test]
    fn threshold_conductance_roundtrip() {
        for v in [0.1, 0.5, 0.9, 1.2] {
            let (gu, gd) = threshold_to_conductances(v);
            let back = conductances_to_threshold(gu, gd);
            assert!((back - v).abs() < 0.02, "v={v} back={back}");
        }
    }

    #[test]
    fn ideal_window_is_programmed_window() {
        let mut r = rng();
        let c = AcamCell::program(CellKind::Charging6T4R, 0.3, 0.8, &Variability::ideal(), &mut r);
        let (lo, hi) = c.nominal_window();
        assert!((lo - 0.3).abs() < 0.02 && (hi - 0.8).abs() < 0.02, "({lo},{hi})");
    }

    #[test]
    fn charging_cell_matches_inside_window() {
        let mut r = rng();
        let c = AcamCell::program(CellKind::Charging6T4R, 0.2, 0.7, &Variability::ideal(), &mut r);
        let inside = c.response(0.5, &Variability::ideal(), &mut r);
        assert!(inside.matched && inside.i_charge > 0.0);
        let outside = c.response(1.0, &Variability::ideal(), &mut r);
        assert!(!outside.matched && outside.i_charge == 0.0);
    }

    #[test]
    fn precharging_cell_discharges_correct_line() {
        let mut r = rng();
        let c = AcamCell::program(CellKind::Precharging3T1R, 0.4, 0.6, &Variability::ideal(), &mut r);
        let below = c.response(0.1, &Variability::ideal(), &mut r);
        assert!(!below.matched && below.i_dis_low > 0.0 && below.i_dis_high == 0.0);
        let above = c.response(0.9, &Variability::ideal(), &mut r);
        assert!(!above.matched && above.i_dis_high > 0.0 && above.i_dis_low == 0.0);
        let inside = c.response(0.5, &Variability::ideal(), &mut r);
        assert!(inside.matched && inside.i_dis_low == 0.0 && inside.i_dis_high == 0.0);
    }

    #[test]
    fn discharge_scales_with_violation() {
        let mut r = rng();
        let c = AcamCell::program(CellKind::Precharging3T1R, 0.4, 0.6, &Variability::ideal(), &mut r);
        let near = c.response(0.65, &Variability::ideal(), &mut r);
        let far = c.response(0.9, &Variability::ideal(), &mut r);
        assert!(far.i_dis_high > near.i_dis_high);
    }

    #[test]
    fn binary_windows_encode_bits() {
        // The program-time mapping for binary templates: bit b -> window
        // [V(b - 0.5), V(b + 0.5)] through the affine feature->voltage map.
        // A 0-bit cell must match V(0) and reject V(1), and vice versa.
        use super::super::feature_to_voltage as v;
        let mut r = rng();
        let ideal = Variability::ideal();
        let c0 = AcamCell::program(CellKind::Charging6T4R, v(-0.5), v(0.5), &ideal, &mut r);
        assert!(c0.response(v(0.0), &ideal, &mut r).matched);
        assert!(!c0.response(v(1.0), &ideal, &mut r).matched);
        let c1 = AcamCell::program(CellKind::Charging6T4R, v(0.5), v(1.5), &ideal, &mut r);
        assert!(c1.response(v(1.0), &ideal, &mut r).matched);
        assert!(!c1.response(v(0.0), &ideal, &mut r).matched);
    }

    #[test]
    fn analogue_9t4r_grades_current_with_distance() {
        let mut r = rng();
        let ideal = Variability::ideal();
        let c = AcamCell::program(CellKind::Analogue9T4R, 0.4, 0.6, &ideal, &mut r);
        let inside = c.response(0.5, &ideal, &mut r);
        assert!(inside.matched && (inside.i_charge - I_LIMIT).abs() < 1e-12);
        // A near-miss still contributes current, graded by overdrive.
        let near = c.response(0.65, &ideal, &mut r);
        let far = c.response(0.75, &ideal, &mut r);
        assert!(!near.matched && near.i_charge > 0.0);
        assert!(far.i_charge < near.i_charge);
        // Beyond the roll-off span the cell contributes nothing — binary
        // query voltages (1 V apart) are fully rejected, preserving Eq. 8.
        let wrong_bit = c.response(0.6 + V_ROLLOFF_9T4R + 0.01, &ideal, &mut r);
        assert_eq!(wrong_bit.i_charge, 0.0);
        assert!(c.response(0.1, &ideal, &mut r).i_charge == 0.0);
    }

    #[test]
    fn variability_perturbs_window() {
        let mut r = rng();
        let noisy = Variability { program_sigma: 0.2, ..Default::default() };
        let c = AcamCell::program(CellKind::Charging6T4R, 0.3, 0.8, &noisy, &mut r);
        let (lo, hi) = c.nominal_window();
        // Window moved, but stays ordered and in-rail.
        assert!(lo <= hi && lo >= 0.0 && hi <= VDD);
    }
}

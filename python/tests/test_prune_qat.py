"""Pruning schedule (Eq. 5-7) and QAT fake-quantisation invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.config import PruneConfig, StudentConfig
from compile.model import init_student
from compile.prune import (
    apply_masks,
    global_threshold,
    make_masks,
    polynomial_sparsity,
    sparsity_of,
)
from compile.qat import fake_quant, quantize_params

RNG = np.random.default_rng(3)


def _student_params():
    return init_student(StudentConfig(), jax.random.PRNGKey(7))[0]


def test_polynomial_schedule_endpoints():
    cfg = PruneConfig(initial_sparsity=0.5, final_sparsity=0.8, pruning_steps=8)
    assert_allclose(polynomial_sparsity(0, cfg), 0.5, rtol=1e-9)
    assert_allclose(polynomial_sparsity(8, cfg), 0.8, rtol=1e-9)


def test_polynomial_schedule_monotone():
    cfg = PruneConfig(pruning_steps=10)
    vals = [polynomial_sparsity(t, cfg) for t in range(11)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))


def test_polynomial_schedule_cubic_shape():
    """Eq. 5 at t = n_t/2: s = s_f + (s_i - s_f) * 0.125."""
    cfg = PruneConfig(initial_sparsity=0.5, final_sparsity=0.8, pruning_steps=8)
    assert_allclose(polynomial_sparsity(4, cfg), 0.8 + (0.5 - 0.8) * 0.125, rtol=1e-9)


def test_global_threshold_is_percentile():
    params = _student_params()
    th = global_threshold(params, 0.6)
    mags = np.concatenate(
        [
            np.abs(np.asarray(leaf)).ravel()
            for path, leaf in jax.tree_util.tree_leaves_with_path(params)
            if path[-1].key == "w" and path[0].key != "head"
        ]
    )
    assert_allclose(th, np.quantile(mags, 0.6), rtol=1e-6)


def test_masks_hit_target_sparsity():
    params = _student_params()
    for target in (0.5, 0.8):
        masks = make_masks(params, target)
        assert abs(sparsity_of(params, masks) - target) < 0.02


def test_masks_preserve_head_and_biases():
    """The head feeds the softmax baseline; ACAM-aware pruning leaves it and
    all biases dense."""
    params = _student_params()
    masks = make_masks(params, 0.8)
    assert float(jnp.min(masks["head"]["w"])) == 1.0
    assert float(jnp.min(masks["conv1"]["b"])) == 1.0


def test_apply_masks_zeroes_exactly():
    params = _student_params()
    masks = make_masks(params, 0.7)
    pruned = apply_masks(params, masks)
    w = np.asarray(pruned["conv3"]["w"])
    m = np.asarray(masks["conv3"]["w"])
    assert (w[m == 0] == 0).all()
    assert_allclose(w[m == 1], np.asarray(params["conv3"]["w"])[m == 1])


# ---------------------------------------------------------------------------
# QAT
# ---------------------------------------------------------------------------


def test_fake_quant_levels():
    """8-bit symmetric: at most 255 distinct dequantised levels."""
    w = jnp.asarray(RNG.normal(size=(64, 64)).astype(np.float32))
    q = np.asarray(fake_quant(w, bits=8))
    assert len(np.unique(q)) <= 255


def test_fake_quant_bounded_error():
    w = jnp.asarray(RNG.normal(size=(1000,)).astype(np.float32))
    q = np.asarray(fake_quant(w, bits=8))
    scale = float(jnp.max(jnp.abs(w))) / 127
    assert np.max(np.abs(q - np.asarray(w))) <= scale * 0.5 + 1e-7


def test_fake_quant_ste_gradient_is_identity():
    w = jnp.asarray([0.3, -0.7, 0.01])
    g = jax.grad(lambda x: jnp.sum(fake_quant(x) * jnp.asarray([1.0, 2.0, 3.0])))(w)
    assert_allclose(np.asarray(g), [1.0, 2.0, 3.0], rtol=1e-6)


def test_quantize_params_idempotent():
    params = _student_params()
    q1 = quantize_params(params)
    q2 = quantize_params(q1)
    for a, b in zip(jax.tree_util.tree_leaves(q1), jax.tree_util.tree_leaves(q2)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


def test_quantize_params_keeps_zeros():
    """Pruned (zero) weights stay exactly zero after quantisation — sparsity
    survives deployment."""
    params = _student_params()
    masks = make_masks(params, 0.8)
    pruned = apply_masks(params, masks)
    q = quantize_params(pruned)
    w = np.asarray(q["conv3"]["w"])
    assert (w[np.asarray(masks["conv3"]["w"]) == 0] == 0).all()

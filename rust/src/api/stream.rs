//! Streaming (pull-parser) decode of v1 request bodies — the zero-tree twin
//! of [`wire`](super::wire)'s `ClassifyRequest::from_value`.
//!
//! The gateway's hot path is "read three small fields and one huge number
//! array": building a full [`crate::jsonlite::Value`] tree first means a
//! `BTreeMap` plus one enum allocation per pixel, all thrown away
//! immediately.  This module scans the document once with
//! [`crate::jsonlite::stream::PullParser`] and decodes `image` straight into
//! a pre-sized `Vec<f32>`.
//!
//! **Equivalence contract** (enforced by `rust/tests/ingest_fuzz.rs` and the
//! in-module tests): for every input string, [`decode_classify_request`]
//! returns exactly what `jsonlite::parse` + `ClassifyRequest::from_value`
//! returns — same `Ok` fields bit for bit, or the same [`ApiError`] code
//! *and message*.  Three tree-path behaviours need deliberate machinery:
//!
//! * **Syntax errors win.**  The tree path parses the whole document before
//!   looking at any field, so `{"image": "x"` is `MALFORMED_REQUEST`, never
//!   the `'image'` schema error.  The streaming path therefore *defers*
//!   schema errors: it keeps scanning (and validating) to the end of the
//!   document and only then reports them.
//! * **Fixed error priority.**  `from_value` checks image → top_k → backend
//!   → return_features → request_id → deadline_ms regardless of document
//!   order; the per-field result slots here are read out in that same order.
//! * **Duplicate keys are last-wins** (the tree's `BTreeMap::insert`): a
//!   later occurrence of a key replaces the earlier value *or error* in its
//!   slot.

use crate::config::Backend;
use crate::jsonlite::stream::{Kind, PullParser};
use crate::jsonlite::ParseError;

use super::{ApiError, ClassifyRequest, ErrorCode};

fn bad(msg: impl Into<String>) -> ApiError {
    ApiError::new(ErrorCode::InvalidArgument, msg)
}

/// The gateway's wrapping of a JSON syntax error (same text as its tree
/// path: `"invalid JSON: json parse error at byte N: ..."`).
fn malformed(e: &ParseError) -> ApiError {
    ApiError::new(ErrorCode::MalformedRequest, format!("invalid JSON: {e}"))
}

/// Decode one `POST /v1/classify` body.  `image_len_hint` pre-sizes the
/// pixel buffer (the deployment's `caps().image_len`; 0 is fine).
pub fn decode_classify_request(
    text: &str,
    image_len_hint: usize,
) -> Result<ClassifyRequest, ApiError> {
    let mut p = PullParser::new(text);
    p.skip_ws();
    let item = decode_request_value(&mut p, image_len_hint).map_err(|e| malformed(&e))?;
    p.end().map_err(|e| malformed(&e))?;
    item
}

/// Decode a `POST /v1/classify/batch` envelope `{"requests": [...]}`,
/// handing each item to `submit` *as soon as it is decoded* — with a
/// submitting closure, items enter the serving queue while later items are
/// still being parsed, so one HTTP batch co-batches in the dynamic batcher
/// even mid-parse.
///
/// Per-item schema errors go to `submit` (which typically maps them to
/// per-item error envelopes); a syntax error anywhere fails the whole call
/// with `MALFORMED_REQUEST`, and a missing/ill-typed `"requests"` key fails
/// it with the envelope `INVALID_ARGUMENT` — both exactly as the tree path
/// does.  Duplicate `"requests"` keys are last-wins: earlier submissions are
/// dropped from the returned list (their responses are discarded).
pub fn decode_batch_envelope<P>(
    text: &str,
    image_len_hint: usize,
    mut submit: impl FnMut(Result<ClassifyRequest, ApiError>) -> P,
) -> Result<Vec<P>, ApiError> {
    let mut p = PullParser::new(text);
    p.skip_ws();
    let envelope =
        scan_envelope(&mut p, image_len_hint, &mut submit).map_err(|e| malformed(&e))?;
    p.end().map_err(|e| malformed(&e))?;
    envelope.ok_or_else(|| bad("body must be {\"requests\": [...]}"))
}

/// Scan the batch envelope object.  `Ok(None)` = valid JSON but not an
/// object with a `"requests"` array (deferred envelope error).
fn scan_envelope<P>(
    p: &mut PullParser,
    hint: usize,
    submit: &mut impl FnMut(Result<ClassifyRequest, ApiError>) -> P,
) -> Result<Option<Vec<P>>, ParseError> {
    if p.peek_kind()? != Kind::Object {
        p.skip_value()?;
        return Ok(None);
    }
    p.begin_object()?;
    // Outer Option: key seen at all; inner: value was an array.
    let mut slot: Option<Option<Vec<P>>> = None;
    let mut first = true;
    while let Some(key) = p.next_key(&mut first)? {
        if key == "requests" {
            if p.peek_kind()? == Kind::Array {
                p.begin_array()?;
                let mut items = Vec::new();
                let mut ef = true;
                while p.next_element(&mut ef)? {
                    let item = decode_request_value(p, hint)?;
                    items.push(submit(item));
                }
                slot = Some(Some(items));
            } else {
                p.skip_value()?;
                slot = Some(None);
            }
        } else {
            p.skip_value()?;
        }
    }
    Ok(slot.flatten())
}

/// How the `image` field is sourced for this decode.
#[derive(Clone, Copy)]
enum ImageMode {
    /// JSON body: `image` is a required number array; the `usize` pre-sizes
    /// the pixel buffer.
    Json(usize),
    /// Binary meta object ([`super::binary`]): pixels arrive in the binary
    /// frame, so an `image` key is rejected and a missing one is fine.
    Forbidden,
}

/// Decode the meta object of one binary-encoded item (see
/// [`super::binary`]): same fields and semantics as a JSON request, except
/// `image` is forbidden and the returned request's pixel vector is empty
/// (the caller fills it from the frame).
pub(crate) fn decode_meta(text: &str) -> Result<ClassifyRequest, ApiError> {
    let mut p = PullParser::new(text);
    p.skip_ws();
    let item =
        decode_request_mode(&mut p, ImageMode::Forbidden).map_err(|e| malformed(&e))?;
    p.end().map_err(|e| malformed(&e))?;
    item
}

/// Per-field result slots with `from_value`'s read-out order.  `None` =
/// field absent; a later duplicate key overwrites the whole slot (value or
/// error), mirroring the tree's map insert.
#[derive(Default)]
struct Slots {
    image: Option<Result<Vec<f32>, ApiError>>,
    top_k: Option<Result<usize, ApiError>>,
    backend: Option<Result<Backend, ApiError>>,
    return_features: Option<Result<bool, ApiError>>,
    request_id: Option<Result<String, ApiError>>,
    deadline_ms: Option<Result<u64, ApiError>>,
}

impl Slots {
    fn finish(self, image_required: bool) -> Result<ClassifyRequest, ApiError> {
        let image = match self.image {
            Some(r) => r?,
            None if image_required => return Err(bad("missing required field 'image'")),
            None => Vec::new(),
        };
        let mut req = ClassifyRequest::new(image);
        if let Some(r) = self.top_k {
            req.top_k = r?;
        }
        if let Some(r) = self.backend {
            req.backend = Some(r?);
        }
        if let Some(r) = self.return_features {
            req.return_features = r?;
        }
        if let Some(r) = self.request_id {
            req.request_id = Some(r?);
        }
        if let Some(r) = self.deadline_ms {
            req.deadline_ms = Some(r?);
        }
        Ok(req)
    }
}

/// Decode one request object at the cursor (document root or a batch
/// element).  Outer `Err` = syntax error (aborts the call as
/// `MALFORMED_REQUEST`); inner `Err` = schema error for this item.
fn decode_request_value(
    p: &mut PullParser,
    hint: usize,
) -> Result<Result<ClassifyRequest, ApiError>, ParseError> {
    decode_request_mode(p, ImageMode::Json(hint))
}

fn decode_request_mode(
    p: &mut PullParser,
    mode: ImageMode,
) -> Result<Result<ClassifyRequest, ApiError>, ParseError> {
    if p.peek_kind()? != Kind::Object {
        p.skip_value()?;
        return Ok(Err(bad("request body must be a JSON object")));
    }
    p.begin_object()?;
    let mut slots = Slots::default();
    let mut first = true;
    while let Some(key) = p.next_key(&mut first)? {
        match key.as_str() {
            "image" => match mode {
                ImageMode::Json(hint) => slots.image = Some(read_image(p, hint)?),
                ImageMode::Forbidden => {
                    p.skip_value()?;
                    slots.image = Some(Err(bad(
                        "'image' is not allowed in binary meta (pixels come from the frame)",
                    )));
                }
            },
            "top_k" => slots.top_k = Some(read_top_k(p)?),
            "backend" => slots.backend = Some(read_backend(p)?),
            "return_features" => slots.return_features = Some(read_return_features(p)?),
            "request_id" => slots.request_id = Some(read_request_id(p)?),
            "deadline_ms" => slots.deadline_ms = Some(read_deadline_ms(p)?),
            // Unknown fields: ignored (additive evolution) but still
            // syntax-validated.
            _ => p.skip_value()?,
        }
    }
    Ok(slots.finish(matches!(mode, ImageMode::Json(_))))
}

/// `image`: numbers decode straight into the output buffer (f64 → f32 with
/// the same `as` cast the tree's `as_f32_vec` uses).  On the first
/// non-number element the rest of the array is validated-and-skipped so the
/// schema error can still be out-prioritised by a later syntax error.
fn read_image(
    p: &mut PullParser,
    hint: usize,
) -> Result<Result<Vec<f32>, ApiError>, ParseError> {
    if p.peek_kind()? != Kind::Array {
        p.skip_value()?;
        return Ok(Err(bad("'image' must be an array of numbers")));
    }
    p.begin_array()?;
    let mut out = Vec::with_capacity(hint);
    let mut first = true;
    while p.next_element(&mut first)? {
        if p.peek_kind()? == Kind::Num {
            out.push(p.read_f64()? as f32);
        } else {
            p.skip_value()?;
            while p.next_element(&mut first)? {
                p.skip_value()?;
            }
            return Ok(Err(bad("'image' must be an array of numbers")));
        }
    }
    Ok(Ok(out))
}

fn read_top_k(p: &mut PullParser) -> Result<Result<usize, ApiError>, ParseError> {
    if p.peek_kind()? != Kind::Num {
        p.skip_value()?;
        return Ok(Err(bad("'top_k' must be a non-negative integer")));
    }
    let f = p.read_f64()?;
    // Same predicate as the tree path's filter (NaN/∞ fall through to the
    // error arm because the comparisons are false).
    if !(f.fract() == 0.0 && f >= 0.0) {
        return Ok(Err(bad("'top_k' must be a non-negative integer")));
    }
    let k = f as usize;
    if k == 0 {
        return Ok(Err(bad("'top_k' must be >= 1")));
    }
    Ok(Ok(k))
}

fn read_backend(p: &mut PullParser) -> Result<Result<Backend, ApiError>, ParseError> {
    if p.peek_kind()? != Kind::Str {
        p.skip_value()?;
        return Ok(Err(bad("'backend' must be a string")));
    }
    let name = p.read_string()?;
    Ok(name
        .parse::<Backend>()
        .map_err(|_| bad(format!("unknown backend: {name}"))))
}

fn read_return_features(p: &mut PullParser) -> Result<Result<bool, ApiError>, ParseError> {
    if p.peek_kind()? != Kind::Bool {
        p.skip_value()?;
        return Ok(Err(bad("'return_features' must be a boolean")));
    }
    Ok(Ok(p.read_bool()?))
}

fn read_request_id(p: &mut PullParser) -> Result<Result<String, ApiError>, ParseError> {
    if p.peek_kind()? != Kind::Str {
        p.skip_value()?;
        return Ok(Err(bad("'request_id' must be a string")));
    }
    Ok(Ok(p.read_string()?))
}

fn read_deadline_ms(p: &mut PullParser) -> Result<Result<u64, ApiError>, ParseError> {
    if p.peek_kind()? != Kind::Num {
        p.skip_value()?;
        return Ok(Err(bad("'deadline_ms' must be a non-negative integer")));
    }
    let f = p.read_f64()?;
    // Same predicate as the tree path's filter.
    if !(f.fract() == 0.0 && f >= 0.0) {
        return Ok(Err(bad("'deadline_ms' must be a non-negative integer")));
    }
    let d = f as u64;
    if d == 0 {
        return Ok(Err(bad(
            "'deadline_ms' must be >= 1 (omit it for no deadline)",
        )));
    }
    Ok(Ok(d))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonlite;

    /// The gateway's tree path (parse_body + from_value), inlined as the
    /// parity oracle.
    fn tree_decode(text: &str) -> Result<ClassifyRequest, ApiError> {
        let v = jsonlite::parse(text).map_err(|e| malformed(&e))?;
        ClassifyRequest::from_value(&v)
    }

    fn tree_decode_batch(text: &str) -> Result<Vec<Result<ClassifyRequest, ApiError>>, ApiError> {
        let doc = jsonlite::parse(text).map_err(|e| malformed(&e))?;
        let items = doc
            .get("requests")
            .and_then(jsonlite::Value::as_array)
            .ok_or_else(|| bad("body must be {\"requests\": [...]}"))?;
        Ok(items.iter().map(ClassifyRequest::from_value).collect())
    }

    fn assert_req_eq(a: &ClassifyRequest, b: &ClassifyRequest, ctx: &str) {
        let ab: Vec<u32> = a.image.iter().map(|p| p.to_bits()).collect();
        let bb: Vec<u32> = b.image.iter().map(|p| p.to_bits()).collect();
        assert_eq!(ab, bb, "image bits on {ctx}");
        assert_eq!(a.top_k, b.top_k, "top_k on {ctx}");
        assert_eq!(a.backend, b.backend, "backend on {ctx}");
        assert_eq!(a.return_features, b.return_features, "return_features on {ctx}");
        assert_eq!(a.request_id, b.request_id, "request_id on {ctx}");
        assert_eq!(a.deadline_ms, b.deadline_ms, "deadline_ms on {ctx}");
    }

    fn assert_parity(text: &str) {
        match (tree_decode(text), decode_classify_request(text, 4)) {
            (Ok(t), Ok(s)) => assert_req_eq(&t, &s, text),
            (Err(t), Err(s)) => {
                assert_eq!(t.code, s.code, "error code on {text:?}");
                assert_eq!(t.message, s.message, "error message on {text:?}");
            }
            (t, s) => panic!(
                "accept/reject parity on {text:?}: tree {:?} vs stream {:?}",
                t.map(|r| r.image.len()),
                s.map(|r| r.image.len())
            ),
        }
    }

    #[test]
    fn single_request_parity() {
        for text in [
            // Valid shapes.
            r#"{"image": [1, 2.5, -0.5]}"#,
            r#"{"image": [], "top_k": 3}"#,
            r#"{"image": [0.1307], "backend": "sim", "return_features": true, "request_id": "r-1"}"#,
            r#"{"image": [1], "future_field": {"x": [1, 2]}}"#,
            // Schema errors (fixed priority, messages must match).
            r#"{}"#,
            r#"{"image": "nope"}"#,
            r#"{"image": [1, "x", 2]}"#,
            r#"{"image": [1, null]}"#,
            r#"{"image": {"a": 1}}"#,
            r#"{"image": [1], "top_k": 0}"#,
            r#"{"image": [1], "top_k": 1.5}"#,
            r#"{"image": [1], "top_k": -1}"#,
            r#"{"image": [1], "top_k": "2"}"#,
            r#"{"image": [1], "backend": "cuda"}"#,
            r#"{"image": [1], "backend": 7}"#,
            r#"{"image": [1], "return_features": "yes"}"#,
            r#"{"image": [1], "request_id": 7}"#,
            r#"{"image": [1], "deadline_ms": 250}"#,
            r#"{"image": [1], "deadline_ms": 0}"#,
            r#"{"image": [1], "deadline_ms": -5}"#,
            r#"{"image": [1], "deadline_ms": 1.5}"#,
            r#"{"image": [1], "deadline_ms": "soon"}"#,
            r#"[1, 2]"#,
            r#""just a string""#,
            "5",
            // Error priority: image error reported before top_k error,
            // regardless of document order.
            r#"{"top_k": 0, "image": "bad"}"#,
            r#"{"top_k": 0}"#,
            // Duplicate keys: last wins, for values and errors alike.
            r#"{"image": "bad", "image": [1, 2]}"#,
            r#"{"image": [1, 2], "image": "bad"}"#,
            r#"{"image": [1], "top_k": 0, "top_k": 2}"#,
            // Syntax errors must out-prioritise schema errors.
            r#"{"image": "x""#,
            r#"{"image": [1, "x", }"#,
            r#"{"image": [1]} trailing"#,
            r#"{"image": [1,]}"#,
            r#"{"image": [01e]}"#,
            "{",
            "",
            "not json",
        ] {
            assert_parity(text);
        }
    }

    #[test]
    fn image_hint_is_only_a_hint() {
        let req = decode_classify_request(r#"{"image": [1, 2, 3, 4, 5]}"#, 2).unwrap();
        assert_eq!(req.image, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let req = decode_classify_request(r#"{"image": [1]}"#, 1024).unwrap();
        assert_eq!(req.image, vec![1.0]);
    }

    #[test]
    fn batch_envelope_parity() {
        for text in [
            r#"{"requests": []}"#,
            r#"{"requests": [{"image": [1, 2]}, {"image": "bad"}, {"top_k": 1}]}"#,
            r#"{"requests": [{"image": [1]}], "extra": true}"#,
            // Envelope errors (valid JSON, wrong shape).
            r#"{}"#,
            r#"{"requests": 5}"#,
            r#"[{"image": [1]}]"#,
            // Duplicate envelope keys: last wins.
            r#"{"requests": 5, "requests": [{"image": [1]}]}"#,
            r#"{"requests": [{"image": [1]}], "requests": 5}"#,
            r#"{"requests": [{"image": [1]}], "requests": [{"image": [2]}]}"#,
            // Syntax errors beat envelope errors.
            r#"{"requests": 5"#,
            r#"{"requests": [{"image": [1]}]"#,
        ] {
            let tree = tree_decode_batch(text);
            let stream = decode_batch_envelope(text, 4, |r| r);
            match (tree, stream) {
                (Ok(t), Ok(s)) => {
                    assert_eq!(t.len(), s.len(), "item count on {text:?}");
                    for (i, (ti, si)) in t.iter().zip(&s).enumerate() {
                        match (ti, si) {
                            (Ok(a), Ok(b)) => assert_req_eq(a, b, &format!("{text:?}[{i}]")),
                            (Err(a), Err(b)) => {
                                assert_eq!(a.code, b.code, "{text:?}[{i}]");
                                assert_eq!(a.message, b.message, "{text:?}[{i}]");
                            }
                            _ => panic!("item parity on {text:?}[{i}]"),
                        }
                    }
                }
                (Err(t), Err(s)) => {
                    assert_eq!(t.code, s.code, "on {text:?}");
                    assert_eq!(t.message, s.message, "on {text:?}");
                }
                (t, s) => panic!(
                    "envelope parity on {text:?}: tree ok={} stream ok={}",
                    t.is_ok(),
                    s.is_ok()
                ),
            }
        }
    }

    #[test]
    fn batch_submit_sees_items_in_order() {
        let mut seen = Vec::new();
        let got = decode_batch_envelope(
            r#"{"requests": [{"image": [1]}, {"image": [2, 3]}]}"#,
            2,
            |r| {
                seen.push(r.as_ref().map(|req| req.image.len()).ok().unwrap_or(0));
                r.map(|req| req.image)
            },
        )
        .unwrap();
        assert_eq!(seen, [1, 2]);
        assert_eq!(got.len(), 2);
        assert_eq!(got[1].as_ref().unwrap(), &vec![2.0, 3.0]);
    }
}

//! Layer-3 coordinator: the serving system around the hybrid classifier.
//!
//! * [`batcher`] — dynamic batching policy (size + deadline, artifact-size
//!   padding);
//! * [`pipeline`] — image -> front-end engine (pure-Rust interpreter or
//!   PJRT, via the [`crate::runtime::FrontEnd`] trait) -> binarise ->
//!   back-end (ACAM sim / digital matcher / softmax baseline) -> class +
//!   energy;
//! * [`server`] — the event loop: bounded request queue with backpressure, a
//!   dedicated worker thread owning the engine state, async-friendly
//!   handles speaking the v1 [`crate::api`] types;
//! * [`metrics`] — lock-free counters, gauges, latency histograms, energy
//!   ledger, Prometheus rendering.

pub mod batcher;
pub mod metrics;
pub mod oneshot;
pub mod pipeline;
pub mod server;

pub use metrics::{Metrics, Snapshot};
pub use pipeline::{Evaluation, Pipeline};
pub use server::{Caps, Handle, Server};

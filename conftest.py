"""Repo-root pytest shim: make `compile.*` importable when the suite is
invoked as `pytest python/tests/` from the repository root (the Makefile's
`make test` cds into python/ instead)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))

//! Dynamic batching policy — pure logic, independent of the transport, so it
//! is unit- and property-testable without a running PJRT client.
//!
//! The batcher assembles incoming requests into batches bounded by
//! `max_batch` items and `max_wait` since the *first* queued item, then the
//! router pads each batch up to the nearest exported artifact batch size
//! (1 / 8 / 32 by default) — the classic dynamic-batching trade between
//! latency (small batches dispatch sooner) and throughput (bigger batches
//! amortise dispatch overhead).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Assemble one batch from a channel: blocks for the first item, then drains
/// until `max_batch` items are held or `max_wait` has elapsed since the first
/// item arrived.  Returns `None` when the channel is closed and empty.
pub fn assemble<T>(rx: &Receiver<T>, max_batch: usize, max_wait: Duration) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + max_wait;
    let mut batch = Vec::with_capacity(max_batch);
    batch.push(first);
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Choose the smallest exported batch size that fits `n` (or the largest if
/// `n` exceeds them all), returning `(artifact_batch, padding)`.
pub fn pad_to_artifact(n: usize, exported: &[usize]) -> (usize, usize) {
    debug_assert!(!exported.is_empty());
    let mut sizes = exported.to_vec();
    sizes.sort_unstable();
    for &b in &sizes {
        if b >= n {
            return (b, b - n);
        }
    }
    let b = *sizes.last().unwrap();
    (b, 0) // caller splits batches larger than the max artifact
}

/// Split an oversized batch into artifact-sized chunks (last chunk padded).
pub fn chunks_for(n: usize, exported: &[usize]) -> Vec<(usize, usize)> {
    let max = *exported.iter().max().unwrap();
    let mut out = Vec::new();
    let mut rest = n;
    while rest > max {
        out.push((max, 0));
        rest -= max;
    }
    if rest > 0 {
        out.push(pad_to_artifact(rest, exported));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn assemble_collects_up_to_max() {
        let (tx, rx) = sync_channel(16);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let b = assemble(&rx, 3, Duration::from_millis(5)).unwrap();
        assert_eq!(b, vec![0, 1, 2]);
        let b2 = assemble(&rx, 3, Duration::from_millis(5)).unwrap();
        assert_eq!(b2, vec![3, 4]);
    }

    #[test]
    fn assemble_times_out_with_partial_batch() {
        let (tx, rx) = sync_channel(16);
        tx.send(42).unwrap();
        let t0 = Instant::now();
        let b = assemble(&rx, 8, Duration::from_millis(20)).unwrap();
        assert_eq!(b, vec![42]);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn assemble_deadline_counts_from_first_item() {
        // Items that arrive after max_wait has elapsed since the FIRST item
        // belong to the next batch, even though the channel is non-empty by
        // the time the deadline check runs.
        let (tx, rx) = sync_channel(16);
        tx.send(1).unwrap();
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            tx.send(2).unwrap();
            tx // keep the channel open past assemble's return
        });
        let t0 = Instant::now();
        let b = assemble(&rx, 8, Duration::from_millis(20)).unwrap();
        assert_eq!(b, vec![1], "late item must not join the flushed batch");
        assert!(t0.elapsed() < Duration::from_millis(140));
        let tx = sender.join().unwrap();
        let b2 = assemble(&rx, 8, Duration::from_millis(5)).unwrap();
        assert_eq!(b2, vec![2]);
        drop(tx);
    }

    #[test]
    fn assemble_full_batch_returns_before_deadline() {
        // max_batch items are already queued: assemble must not sit out the
        // deadline, it returns the full batch immediately.
        let (tx, rx) = sync_channel(16);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        let t0 = Instant::now();
        let b = assemble(&rx, 4, Duration::from_secs(5)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(1), "must not wait out max_wait");
    }

    #[test]
    fn assemble_flushes_partial_batch_on_disconnect() {
        // Channel closes while a partial batch is held: the held items are
        // flushed as a final batch (graceful shutdown), and the NEXT call
        // returns None.
        let (tx, rx) = sync_channel(16);
        tx.send(10).unwrap();
        tx.send(11).unwrap();
        drop(tx);
        let b = assemble(&rx, 8, Duration::from_millis(50)).unwrap();
        assert_eq!(b, vec![10, 11]);
        assert!(assemble(&rx, 8, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn assemble_zero_wait_dispatches_singletons() {
        let (tx, rx) = sync_channel(16);
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        // max_wait = 0: the deadline is already reached when the first item
        // is in hand, so each batch carries exactly one item.
        for want in 0..3 {
            let b = assemble(&rx, 8, Duration::ZERO).unwrap();
            assert_eq!(b, vec![want]);
        }
        drop(tx);
    }

    #[test]
    fn assemble_none_on_closed_empty_channel() {
        let (tx, rx) = sync_channel::<u32>(1);
        drop(tx);
        assert!(assemble(&rx, 4, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn pad_picks_smallest_fit() {
        let exported = [1, 8, 32];
        assert_eq!(pad_to_artifact(1, &exported), (1, 0));
        assert_eq!(pad_to_artifact(2, &exported), (8, 6));
        assert_eq!(pad_to_artifact(8, &exported), (8, 0));
        assert_eq!(pad_to_artifact(9, &exported), (32, 23));
    }

    #[test]
    fn chunks_split_oversized() {
        let exported = [1, 8, 32];
        assert_eq!(chunks_for(70, &exported), vec![(32, 0), (32, 0), (8, 2)]);
        assert_eq!(chunks_for(5, &exported), vec![(8, 3)]);
    }
}

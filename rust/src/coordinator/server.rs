//! The serving loop: a dedicated worker thread owns the pipeline (the
//! engine trait object is not `Send` — PJRT handles cannot cross threads);
//! callers submit v1 [`ClassifyRequest`]s through a bounded channel (the
//! backpressure boundary) and wait on per-request oneshot channels for
//! [`ClassifyResponse`]s, so multi-threaded front-ends (the HTTP gateway,
//! the CLI demo driver) compose naturally and share one queue semantics.

use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::{
    ApiError, ClassifyOptions, ClassifyRequest, ClassifyResponse, ClassifyResult, ErrorCode,
    Timing,
};
use crate::backend::BackendVariant;
use crate::config::{Backend, ServeConfig};
use crate::error::Result;
use crate::runtime::Meta;
use crate::store::{StoreAdmin, StoreRegistry, TenantTicket, DEFAULT_STORE_ID};

use super::oneshot;

use super::batcher;
use super::metrics::Metrics;
use super::pipeline::Pipeline;

/// One in-flight request (shared with the sharded coordinator in
/// [`super::shard`], which runs the same worker body per shard).
pub(crate) struct Job {
    pub(crate) req: ClassifyRequest,
    pub(crate) enqueued: Instant,
    pub(crate) resp: oneshot::Sender<std::result::Result<ClassifyResponse, ApiError>>,
    /// Tenant admission ticket (holds one quota slot until the job is
    /// delivered, failed, or dropped — the ticket's `Drop` keeps the
    /// per-tenant `in_flight` gauge drift-free on every path).
    pub(crate) tenant: Option<TenantTicket>,
    /// Non-default store binding this job serves from (`None` = default).
    pub(crate) route: Option<Arc<str>>,
}

/// Resolve a request's tenant against the registry and claim a quota slot.
/// Returns the admission ticket plus the store route for the worker
/// (`None` when the tenant is pinned to the default store).
#[allow(clippy::type_complexity)]
pub(crate) fn admit_tenant(
    registry: &StoreRegistry,
    req: &ClassifyRequest,
) -> std::result::Result<(Option<TenantTicket>, Option<Arc<str>>), ApiError> {
    match registry.resolve_tenant(req.request_id.as_deref()) {
        Some(t) => {
            let ticket = t.admit()?;
            let route = if &**ticket.store_id() == DEFAULT_STORE_ID {
                None
            } else {
                Some(Arc::clone(ticket.store_id()))
            };
            Ok((Some(ticket), route))
        }
        None => Ok((None, None)),
    }
}

/// What the deployed pipeline can do — shared with every [`Handle`] clone so
/// submit-time validation (shape, backend availability) and the gateway's
/// `/healthz` never have to reach the worker thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Caps {
    /// Pixels per image (`image_size^2`).
    pub image_len: usize,
    pub num_classes: usize,
    /// Execution engine name (`interp`, `interp-fast`, `pjrt`).
    pub engine: &'static str,
    /// Deployment backend (the default when requests carry no override).
    pub backend: Backend,
    /// Whether the simulated ACAM array was programmed (i.e. whether a
    /// per-request `backend: "acam"` override can be served).
    pub acam_available: bool,
    /// The deployed [`MatchingBackend`] variant behind `acam`-routed
    /// requests (`--backend acam|acam-9t4r|rbf|digital` / `HEC_BACKEND`).
    ///
    /// [`MatchingBackend`]: crate::backend::MatchingBackend
    pub backend_variant: BackendVariant,
}

impl Caps {
    /// The variant name to advertise on responses and `/metrics`: `None`
    /// for the default `acam` variant (wire parity — pre-seam builds had
    /// no such field) and for deployments whose back-end unit was never
    /// programmed (`acam`-routed serving impossible, variant irrelevant).
    pub(crate) fn advertised_variant(&self) -> Option<&'static str> {
        (self.acam_available && self.backend_variant != BackendVariant::Acam)
            .then(|| self.backend_variant.name())
    }
}

impl Caps {
    /// Whether a per-request backend override can be served here.
    pub fn backend_available(&self, b: Backend) -> bool {
        match b {
            Backend::AcamSim => self.acam_available,
            Backend::FeatureCount | Backend::Similarity | Backend::Softmax => true,
        }
    }
}

/// Submit-time request validation against the deployment caps — shared by
/// the single-pipeline [`Handle`] and the shard router so nothing invalid
/// ever reaches a queue, whichever surface accepted the request.
pub(crate) fn validate_request(
    caps: &Caps,
    req: &ClassifyRequest,
) -> std::result::Result<(), ApiError> {
    if req.image.len() != caps.image_len {
        return Err(ApiError::new(
            ErrorCode::InvalidShape,
            format!(
                "image has {} pixels, expected {}",
                req.image.len(),
                caps.image_len
            ),
        ));
    }
    if req.top_k == 0 {
        return Err(ApiError::new(ErrorCode::InvalidArgument, "top_k must be >= 1"));
    }
    if req.top_k > caps.num_classes {
        // Same stable code as top_k == 0: every out-of-range top_k is an
        // INVALID_ARGUMENT, never a silent clamp.
        return Err(ApiError::new(
            ErrorCode::InvalidArgument,
            format!(
                "top_k must be <= num_classes ({}), got {}",
                caps.num_classes, req.top_k
            ),
        ));
    }
    if let Some(b) = req.backend {
        if !caps.backend_available(b) {
            return Err(ApiError::new(
                ErrorCode::BackendUnavailable,
                format!(
                    "backend '{}' is not provisioned in this deployment \
                     (deployed backend: '{}')",
                    b.name(),
                    caps.backend.name()
                ),
            ));
        }
    }
    Ok(())
}

/// Pack a batch's images contiguously and capture per-job options into
/// caller-owned scratch buffers — the front half of the worker body, shared
/// with [`super::shard`].  The worker loops keep `buf`/`opts` alive across
/// batches, so steady-state packing allocates nothing (the buffers grow to
/// the largest batch seen and stay there).
pub(crate) fn pack_batch_into(
    batch: &[Job],
    image_len: usize,
    buf: &mut Vec<f32>,
    opts: &mut Vec<ClassifyOptions>,
) {
    buf.clear();
    opts.clear();
    buf.reserve(batch.len() * image_len);
    opts.reserve(batch.len());
    for job in batch {
        buf.extend_from_slice(&job.req.image);
        opts.push(job.req.options());
    }
}

/// Drop queue-expired jobs from an assembled batch before compute: a job
/// whose `deadline_ms` has already elapsed fails fast with
/// `DEADLINE_EXCEEDED` instead of burning the pipeline on an answer its
/// caller has abandoned.  The caller has already decremented `queue_depth`
/// for the whole assembled batch (the batcher drained these jobs from the
/// channel), so only [`fail_job`]'s accounting applies here.  Jobs without
/// a deadline never expire, and a deadline-free batch takes the early
/// return — zero extra work on the common path.
pub(crate) fn drop_expired_jobs(batch: &mut Vec<Job>, m: &Metrics) {
    if batch.iter().all(|j| j.req.deadline_ms.is_none()) {
        return;
    }
    let now = Instant::now();
    let mut kept = Vec::with_capacity(batch.len());
    for job in batch.drain(..) {
        let waited = now.duration_since(job.enqueued);
        // `>=` so `deadline_ms: 0` always expires — the deterministic
        // "already too late" probe the tests lean on.
        let expired = job
            .req
            .deadline_ms
            .is_some_and(|d| waited >= Duration::from_millis(d));
        if expired {
            let d = job.req.deadline_ms.unwrap_or(0);
            fail_job(
                job,
                ApiError::new(
                    ErrorCode::DeadlineExceeded,
                    format!(
                        "deadline of {d}ms exceeded after {}ms in queue",
                        waited.as_millis()
                    ),
                ),
                m,
            );
        } else {
            kept.push(job);
        }
    }
    *batch = kept;
}

/// Deliver one computed batch back to its waiters (or fail them all with
/// the same error), maintaining the response/error counters, the energy
/// ledger, and the `in_flight` gauge — the back half of the worker body,
/// shared with [`super::shard`].
///
/// `ladder` carries the shard's degradation-ladder observation at dispatch
/// time as `(degraded, backend_state)`; `None` (every deployment without an
/// active ladder) leaves the new v1 fields unset so the wire output is
/// byte-identical to pre-faults builds.  `variant` is the deployment's
/// advertised [`MatchingBackend`] variant name ([`Caps::advertised_variant`]);
/// it stamps responses whose resolved backend is `acam` and drives the
/// per-variant energy/latency series, and is `None` for the default `acam`
/// variant so that wire output and `/metrics` stay byte-identical to
/// pre-seam builds.
///
/// [`MatchingBackend`]: crate::backend::MatchingBackend
#[allow(clippy::too_many_arguments)]
pub(crate) fn deliver_batch(
    batch: Vec<Job>,
    results: std::result::Result<Vec<ClassifyResult>, ApiError>,
    m: &Metrics,
    engine: &'static str,
    dispatched: Instant,
    compute_us: u64,
    shard: Option<usize>,
    ladder: Option<(bool, &'static str)>,
    variant: Option<&'static str>,
) {
    use std::sync::atomic::Ordering::Relaxed;
    match results {
        Ok(results) => {
            for (job, res) in batch.into_iter().zip(results) {
                let queue_us = dispatched.duration_since(job.enqueued).as_micros() as u64;
                let total_us = job.enqueued.elapsed().as_micros() as u64;
                m.latency.record_us(total_us);
                m.latency_for(res.backend).record_us(total_us);
                m.add_energy_nj(res.energy.total_nj());
                let backend_variant = variant.filter(|_| res.backend == Backend::AcamSim);
                if backend_variant.is_some() {
                    m.variant_latency.record_us(total_us);
                    m.add_variant_energy_nj(res.energy.back_end_nj);
                }
                m.responses.fetch_add(1, Relaxed);
                Metrics::gauge_dec(&m.in_flight, 1);
                if let Some(t) = &job.tenant {
                    t.mark_served();
                }
                let _ = job.resp.send(Ok(ClassifyResponse {
                    request_id: job.req.request_id,
                    predictions: res.predictions,
                    energy: res.energy,
                    timing: Timing {
                        queue_us,
                        compute_us,
                    },
                    engine,
                    backend: res.backend,
                    backend_variant,
                    features: res.features,
                    shard,
                    degraded: ladder.map(|(d, _)| d),
                    backend_state: ladder.map(|(_, s)| s.to_string()),
                    store: res.store.as_ref().map(|(id, _)| id.to_string()),
                    store_version: res.store.as_ref().map(|(_, v)| *v),
                    cache: res.cache,
                }));
            }
        }
        Err(api) => {
            for job in batch {
                fail_job(job, api.clone(), m);
            }
        }
    }
}

/// Fail one job with a structured error, maintaining the error counter and
/// the `in_flight` gauge.
pub(crate) fn fail_job(job: Job, err: ApiError, m: &Metrics) {
    m.errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    Metrics::gauge_dec(&m.in_flight, 1);
    let _ = job.resp.send(Err(err));
}

/// Handle for submitting classification requests.
#[derive(Clone)]
pub struct Handle {
    tx: SyncSender<Job>,
    pub metrics: Arc<Metrics>,
    caps: Arc<Caps>,
    admin: StoreAdmin,
    /// Whether the feature cache is enabled — gates the `hec_cache_*`
    /// block in `/metrics` so cache-off exposition text stays byte-identical
    /// to a cache-free build.
    cache_on: bool,
}

impl Handle {
    /// What the deployment can serve (image shape, engine, backends).
    pub fn caps(&self) -> &Caps {
        &self.caps
    }

    /// Submit a request; await the returned receiver for the response.
    /// Fails fast with a structured [`ApiError`] on invalid requests or
    /// backpressure (`QUEUE_FULL`) — nothing invalid reaches the queue.
    #[allow(clippy::type_complexity)]
    pub fn submit(
        &self,
        req: ClassifyRequest,
    ) -> std::result::Result<
        oneshot::Receiver<std::result::Result<ClassifyResponse, ApiError>>,
        ApiError,
    > {
        use std::sync::atomic::Ordering::Relaxed;
        validate_request(&self.caps, &req)?;
        let (tenant, route) = admit_tenant(self.admin.registry(), &req)?;
        let (tx, rx) = oneshot::channel();
        self.metrics.requests.fetch_add(1, Relaxed);
        // Gauges go up BEFORE the job becomes visible to the worker: if they
        // went up after a successful try_send, the worker could decrement
        // first (saturating at 0) and the late increment would drift the
        // gauge upward permanently.
        self.metrics.queue_depth.fetch_add(1, Relaxed);
        self.metrics.in_flight.fetch_add(1, Relaxed);
        match self.tx.try_send(Job {
            req,
            enqueued: Instant::now(),
            resp: tx,
            tenant,
            route,
        }) {
            Ok(()) => Ok(rx),
            Err(e) => {
                Metrics::gauge_dec(&self.metrics.queue_depth, 1);
                Metrics::gauge_dec(&self.metrics.in_flight, 1);
                match e {
                    TrySendError::Full(_) => {
                        self.metrics.errors.fetch_add(1, Relaxed);
                        Err(ApiError::new(
                            ErrorCode::QueueFull,
                            "queue full (backpressure)",
                        ))
                    }
                    TrySendError::Disconnected(_) => Err(ApiError::new(
                        ErrorCode::ServerStopped,
                        "server stopped",
                    )),
                }
            }
        }
    }

    /// Convenience for synchronous callers: top-1 classify on the
    /// deployment backend, blocking.
    pub fn classify_blocking(
        &self,
        image: Vec<f32>,
    ) -> std::result::Result<ClassifyResponse, ApiError> {
        self.submit_blocking(ClassifyRequest::new(image))
    }

    /// Submit any v1 request and block for the response.
    pub fn submit_blocking(
        &self,
        req: ClassifyRequest,
    ) -> std::result::Result<ClassifyResponse, ApiError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| {
            ApiError::new(ErrorCode::Internal, "worker dropped response")
        })?
    }
}

/// The running server (worker thread + handle).
pub struct Server {
    pub handle: Handle,
    worker: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the worker thread.  The pipeline is **constructed inside the
    /// worker** (PJRT handles are not `Send`); construction failure is
    /// reported back through a ready-channel before `start` returns.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = sync_channel::<Job>(cfg.batch.queue_depth);
        let max_batch = cfg.batch.max_batch;
        let max_wait = Duration::from_micros(cfg.batch.max_wait_us);
        let m = Arc::clone(&metrics);
        let (ready_tx, ready_rx) = oneshot::channel::<Result<Caps>>();

        // The registry is built on the caller thread (it is Send; the
        // pipeline is not) so the admin surface exists even while the
        // worker is busy, and publish/admit never block on compute.
        let meta = Meta::load_or_synthetic(&cfg.artifacts_dir)?;
        let registry = StoreRegistry::from_config(&cfg, &meta)?;
        let admin = StoreAdmin::new(Arc::clone(&registry), Arc::new(cfg.clone()));
        let reg_worker = Arc::clone(&registry);
        let cache_on = cfg.resolve_cache().is_some();

        let worker = std::thread::Builder::new()
            .name("hec-serve".into())
            .spawn(move || {
                use std::sync::atomic::Ordering::Relaxed;
                let mut pipeline = match Pipeline::new(&cfg) {
                    Ok(p) => {
                        let caps = Caps {
                            image_len: p.image_len(),
                            num_classes: p.store.num_classes,
                            engine: p.engine_name(),
                            backend: p.backend(),
                            acam_available: p.backend_available(Backend::AcamSim),
                            backend_variant: p.backend_variant(),
                        };
                        let _ = ready_tx.send(Ok(caps));
                        p
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                pipeline.attach_registry(reg_worker);
                let engine = pipeline.engine_name();
                let image_len = pipeline.image_len();
                let variant = (pipeline.backend_available(Backend::AcamSim)
                    && pipeline.backend_variant() != BackendVariant::Acam)
                    .then(|| pipeline.backend_variant().name());
                let mut buf: Vec<f32> = Vec::new();
                let mut opts: Vec<ClassifyOptions> = Vec::new();
                let mut routes: Vec<Option<Arc<str>>> = Vec::new();
                // Content-hash feature cache (None = off: the serving loop
                // below is then bitwise identical to a cache-free build).
                let mut cache = cfg
                    .resolve_cache()
                    .map(|cap| super::cache::FeatureCache::new(cap, cfg.acam.seed ^ 0xCAC4E));
                while let Some(mut batch) = batcher::assemble(&rx, max_batch, max_wait) {
                    let assembled = batch.len();
                    Metrics::gauge_dec(&m.queue_depth, assembled as u64);
                    drop_expired_jobs(&mut batch, &m);
                    if batch.is_empty() {
                        continue;
                    }
                    let n = batch.len();
                    m.batches.fetch_add(1, Relaxed);
                    m.batched_items.fetch_add(n as u64, Relaxed);

                    pack_batch_into(&batch, image_len, &mut buf, &mut opts);
                    routes.clear();
                    if batch.iter().any(|j| j.route.is_some()) {
                        routes.extend(batch.iter().map(|j| j.route.clone()));
                    }
                    let padded = pipeline.padding_for(n);
                    m.padded_slots.fetch_add(padded as u64, Relaxed);

                    // Hot-swap barrier: adopt pending publishes between
                    // batches, never within one.  Publish-time validation
                    // makes adoption infallible; a failure keeps serving
                    // the previous store.
                    let store_version = pipeline.default_store_version();
                    if let Ok(nj) = pipeline.sync_stores() {
                        if nj > 0.0 {
                            m.add_energy_nj(nj);
                        }
                    }
                    if let Some(c) = cache.as_mut() {
                        // Cached bits are binarised under the old store's
                        // thresholds: a default-store swap invalidates all.
                        if pipeline.default_store_version() != store_version {
                            c.flush();
                        }
                    }

                    let dispatched = Instant::now();
                    let results = match cache.as_mut() {
                        Some(c) => {
                            let r = pipeline
                                .classify_batch_cached(&buf, n, &opts, &routes, c)
                                .map_err(ApiError::from);
                            c.publish_to(&m);
                            r
                        }
                        None => pipeline
                            .classify_batch_routed(&buf, n, &opts, &routes)
                            .map_err(ApiError::from),
                    };
                    let compute_us = dispatched.elapsed().as_micros() as u64;
                    m.execute.record_us(compute_us);
                    deliver_batch(
                        batch, results, &m, engine, dispatched, compute_us, None, None, variant,
                    );
                }
            })
            .expect("spawn serving worker");

        let caps = ready_rx.recv().map_err(|_| {
            crate::error::Error::Request("serving worker died during startup".into())
        })??;
        Ok(Server {
            handle: Handle {
                tx,
                metrics,
                caps: Arc::new(caps),
                admin,
                cache_on,
            },
            worker: Some(worker),
        })
    }

    /// Stop accepting requests and join the worker.  (Outstanding `Handle`
    /// clones keep the channel open; the worker exits once the last clone
    /// drops.)
    pub fn shutdown(self) {
        let Server { handle, worker } = self;
        drop(handle);
        if let Some(w) = worker {
            let _ = w.join();
        }
    }
}

impl super::ClassifySurface for Handle {
    fn caps(&self) -> &Caps {
        Handle::caps(self)
    }

    #[allow(clippy::type_complexity)]
    fn submit(
        &self,
        req: ClassifyRequest,
    ) -> std::result::Result<
        oneshot::Receiver<std::result::Result<ClassifyResponse, ApiError>>,
        ApiError,
    > {
        Handle::submit(self, req)
    }

    fn health(&self) -> super::HealthReport {
        super::HealthReport::default()
    }

    fn prometheus_text(&self) -> String {
        let mut out = self.metrics.snapshot().prometheus();
        super::metrics::prometheus_histograms(std::slice::from_ref(&self.metrics), false, &mut out);
        if self.cache_on {
            super::metrics::prometheus_cache(std::slice::from_ref(&self.metrics), false, &mut out);
        }
        if let Some(variant) = self.caps.advertised_variant() {
            super::metrics::prometheus_variant(
                variant,
                std::slice::from_ref(&self.metrics),
                false,
                &mut out,
            );
        }
        let reg = self.admin.registry();
        if reg.advertises() {
            reg.prometheus(&mut out);
        }
        out
    }

    fn store_admin(&self) -> Option<StoreAdmin> {
        Some(self.admin.clone())
    }
}

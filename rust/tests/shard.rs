//! Sharded-coordinator tests: the deterministic concurrency harness this
//! PR exists for.
//!
//! Everything here runs artifact-free, under fixed seeds, with **no
//! sleeps** — orderings are forced with the [`hec::coordinator::shard::Gate`]
//! rendezvous and blocking submits, never raced against wall-clock time.
//!
//! The acceptance gate is the bitwise parity suite: for any shard count
//! N in {1, 2, 4} and both interpreter engines, a ShardSet's predictions
//! and per-stage energy splits are identical to N independent
//! single-pipeline runs with seeds `base + shard_index`, fed the same
//! routed request subsequences.
//!
//! Parameterisation for CI: `HEC_SHARDS` (comma list, e.g. `1,2,4`) and
//! `HEC_ENGINE` (comma list of `interp`/`interp-fast`) narrow the sweeps
//! so the shard-matrix job can split the grid across cells; unset, the
//! full sweep runs.

use hec::api::{ClassifyRequest, ErrorCode};
use hec::config::{Backend, Engine, RoutePolicy, ServeConfig};
use hec::coordinator::shard::{fnv1a, plan_route, Gate, ShardHooks};
use hec::coordinator::{ClassifySurface, Pipeline, ShardSet};
use hec::dataset::SyntheticDataset;

/// An artifacts directory that never exists -> synthetic fallback.
const NO_ARTIFACTS: &str = "/nonexistent-hec-artifacts";

fn cfg(backend: Backend, engine: Engine, shards: usize, policy: RoutePolicy) -> ServeConfig {
    let mut c = ServeConfig {
        artifacts_dir: NO_ARTIFACTS.into(),
        backend,
        engine,
        ..Default::default()
    };
    c.batch.max_batch = 4;
    c.batch.max_wait_us = 0; // serial submits -> singleton batches, no timing
    c.shards.count = shards;
    c.shards.policy = policy;
    c
}

/// Shard counts to sweep: `HEC_SHARDS` env (comma list — the *test-sweep*
/// grammar; the serving binary's `HEC_SHARDS` takes a single integer) or
/// {1, 2, 4}.  An unparsable override panics rather than silently
/// emptying the sweep — the parity gate must never pass vacuously.
fn shard_counts() -> Vec<usize> {
    let counts = match std::env::var("HEC_SHARDS") {
        Ok(v) => v
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .filter(|&n| n >= 1)
            .collect(),
        Err(_) => vec![1, 2, 4],
    };
    assert!(!counts.is_empty(), "HEC_SHARDS override parsed to an empty sweep");
    counts
}

/// Engines to sweep: `HEC_ENGINE` env (comma list) or both interpreters.
/// An unparsable override panics (see [`shard_counts`]).
fn engines() -> Vec<Engine> {
    let engines: Vec<Engine> = match std::env::var("HEC_ENGINE") {
        Ok(v) => v
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect(),
        Err(_) => vec![Engine::Interp, Engine::InterpFast],
    };
    assert!(!engines.is_empty(), "HEC_ENGINE override parsed to an empty sweep");
    engines
}

fn workload(_c: &ServeConfig, n: usize, seed: u64) -> (Vec<f32>, usize) {
    let meta = hec::runtime::Meta::synthetic();
    let ds = SyntheticDataset::new(seed, n, meta.norm.mean as f32, meta.norm.std as f32);
    let (images, _) = ds.batch(0, n);
    let s = meta.artifacts.image_size;
    (images, s * s)
}

/// Everything parity needs from one response, compared with exact
/// (bitwise) equality — no tolerances anywhere in this file.
#[derive(Debug, PartialEq)]
struct Outcome {
    predictions: Vec<(usize, f64)>,
    front_end_nj: f64,
    back_end_nj: f64,
}

/// THE acceptance gate: an N-shard ShardSet under serial round-robin
/// submits is bitwise identical to N independent single-pipeline runs
/// seeded `base + shard_index`, each fed the subsequence round-robin
/// assigns it — for every swept shard count and engine.
#[test]
fn shard_set_predictions_match_independent_pipelines_bitwise() {
    let requests = 16;
    for engine in engines() {
        for n_shards in shard_counts() {
            let c = cfg(Backend::FeatureCount, engine, n_shards, RoutePolicy::RoundRobin);
            let (images, img_len) = workload(&c, requests, 1_000_003);
            let set = ShardSet::start(&c).unwrap();
            assert_eq!(set.handle.shard_count(), n_shards);

            // Serial blocking submits: request i lands on shard i % N by
            // round-robin construction (asserted via the response's shard
            // field), and each shard serves its subsequence in order.
            let mut got: Vec<(usize, Outcome)> = Vec::new();
            for i in 0..requests {
                let mut req =
                    ClassifyRequest::new(images[i * img_len..(i + 1) * img_len].to_vec());
                req.top_k = 3;
                let resp = set.handle.submit_blocking(req).unwrap();
                assert_eq!(
                    resp.shard,
                    Some(i % n_shards),
                    "engine {engine:?}, {n_shards} shards: request {i} misrouted"
                );
                got.push((
                    resp.shard.unwrap(),
                    Outcome {
                        predictions: resp
                            .predictions
                            .iter()
                            .map(|p| (p.class, p.score))
                            .collect(),
                        front_end_nj: resp.energy.front_end_nj,
                        back_end_nj: resp.energy.back_end_nj,
                    },
                ));
            }
            set.shutdown();

            // N independent single-pipeline runs, seeds base + shard index,
            // each fed its routed subsequence in order.
            for s in 0..n_shards {
                let mut sc = c.clone();
                sc.shards.count = 1;
                sc.acam.seed = c.acam.seed.wrapping_add(s as u64);
                let mut p = Pipeline::new(&sc).unwrap();
                let mut routed = got.iter().filter(|(shard, _)| *shard == s);
                for i in (0..requests).filter(|i| i % n_shards == s) {
                    let opts = hec::api::ClassifyOptions {
                        top_k: 3,
                        backend: None,
                        return_features: false,
                    };
                    let want = p
                        .classify_batch_with(
                            &images[i * img_len..(i + 1) * img_len],
                            1,
                            &[opts],
                        )
                        .unwrap()
                        .remove(0);
                    let want = Outcome {
                        predictions: want
                            .predictions
                            .iter()
                            .map(|pr| (pr.class, pr.score))
                            .collect(),
                        front_end_nj: want.energy.front_end_nj,
                        back_end_nj: want.energy.back_end_nj,
                    };
                    let (_, sharded) = routed.next().expect("subsequence length mismatch");
                    assert_eq!(
                        sharded, &want,
                        "engine {engine:?}, {n_shards} shards: request {i} diverged from \
                         the independent shard-{s} pipeline"
                    );
                }
                assert!(routed.next().is_none(), "extra responses on shard {s}");
            }
        }
    }
}

/// The same bitwise parity through the stochastic back-end: the ACAM WTA
/// consumes a per-shard RNG stream, so this pins that shard `i`'s stream
/// (seed `base + i`) advances exactly as an independent pipeline's would.
#[test]
fn shard_set_acam_rng_streams_match_independent_pipelines() {
    let requests = 12;
    for n_shards in shard_counts() {
        let mut c = cfg(Backend::AcamSim, Engine::Interp, n_shards, RoutePolicy::RoundRobin);
        c.acam.variability_level = 1.0; // exercise programming + read noise
        let (images, img_len) = workload(&c, requests, 424_243);
        let set = ShardSet::start(&c).unwrap();
        let mut got = Vec::new();
        for i in 0..requests {
            let resp = set
                .handle
                .classify_blocking(images[i * img_len..(i + 1) * img_len].to_vec())
                .unwrap();
            assert_eq!(resp.shard, Some(i % n_shards));
            got.push((
                resp.predictions[0].class,
                resp.predictions[0].score,
                resp.energy.back_end_nj,
            ));
        }
        set.shutdown();
        for s in 0..n_shards {
            let mut sc = c.clone();
            sc.shards.count = 1;
            sc.acam.seed = c.acam.seed.wrapping_add(s as u64);
            let mut p = Pipeline::new(&sc).unwrap();
            for i in (0..requests).filter(|i| i % n_shards == s) {
                let want = p
                    .classify_batch(&images[i * img_len..(i + 1) * img_len], 1)
                    .unwrap()
                    .remove(0);
                assert_eq!(
                    got[i],
                    (
                        want.top1().class,
                        want.top1().score,
                        want.energy.back_end_nj
                    ),
                    "{n_shards} shards, request {i}: ACAM RNG stream diverged on shard {s}"
                );
            }
        }
    }
}

/// Hash routing is sticky end-to-end: one request id always lands on the
/// same shard; distinct ids spread across shards.
#[test]
fn hash_policy_is_sticky_over_the_live_surface() {
    let c = cfg(Backend::FeatureCount, Engine::Interp, 4, RoutePolicy::Hash);
    let (images, img_len) = workload(&c, 1, 7);
    let set = ShardSet::start(&c).unwrap();
    let img = images[..img_len].to_vec();
    let mut sticky = None;
    for r in 0..5 {
        let mut req = ClassifyRequest::new(img.clone());
        req.request_id = Some("tenant-42".into());
        let resp = set.handle.submit_blocking(req).unwrap();
        let shard = resp.shard.unwrap();
        let expect = (fnv1a("tenant-42") % 4) as usize;
        assert_eq!(shard, expect, "round {r}: sticky id moved");
        sticky = Some(shard);
    }
    let mut seen = std::collections::BTreeSet::new();
    for i in 0..16 {
        let mut req = ClassifyRequest::new(img.clone());
        req.request_id = Some(format!("spread-{i}"));
        seen.insert(set.handle.submit_blocking(req).unwrap().shard.unwrap());
    }
    assert!(seen.len() > 1, "16 distinct ids all stuck to {sticky:?}");
    set.shutdown();
}

/// Least-queue-depth serves the whole workload and stays within range
/// (its ordering properties are pinned by the pure `plan_route` unit
/// tests; live queue occupancy is inherently racy, so this only asserts
/// completion and well-formed shard attribution).
#[test]
fn least_depth_policy_serves_and_attributes_shards() {
    let c = cfg(Backend::FeatureCount, Engine::Interp, 3, RoutePolicy::LeastQueueDepth);
    let (images, img_len) = workload(&c, 9, 99);
    let set = ShardSet::start(&c).unwrap();
    for i in 0..9 {
        let resp = set
            .handle
            .classify_blocking(images[i * img_len..(i + 1) * img_len].to_vec())
            .unwrap();
        assert!(resp.shard.unwrap() < 3);
    }
    assert_eq!(set.handle.snapshot().responses, 9);
    set.shutdown();
}

/// Find a request id the hash policy routes to `shard` out of `n`.
fn sticky_id_for(shard: usize, n: usize, tag: &str) -> String {
    (0..)
        .map(|i| format!("{tag}-{i}"))
        .find(|id| (fnv1a(id) % n as u64) as usize == shard)
        .unwrap()
}

/// Spill semantics, deterministically: a full shard queue spills to the
/// next-best healthy shard; with spill disabled the same submit is
/// QUEUE_FULL.  The worker is parked on a Gate (not a sleep) so queue
/// occupancy is exact at every assert.
#[test]
fn full_shard_spills_to_next_best_before_queue_full() {
    for spill in [true, false] {
        let gate = Gate::new();
        let hold_id = sticky_id_for(0, 2, "hold");
        let mut c = cfg(Backend::FeatureCount, Engine::Interp, 2, RoutePolicy::Hash);
        c.shards.spill = spill;
        c.batch.max_batch = 1;
        c.batch.queue_depth = 1;
        let (images, img_len) = workload(&c, 1, 55);
        let img = images[..img_len].to_vec();
        let set = ShardSet::start_with_hooks(
            &c,
            ShardHooks {
                hold: Some((hold_id.clone(), std::sync::Arc::clone(&gate))),
                ..Default::default()
            },
        )
        .unwrap();

        // Park shard 0's worker on the gate (it has *pulled* the hold job,
        // so the queue is empty again and we control it exactly).
        let mut req = ClassifyRequest::new(img.clone());
        req.request_id = Some(hold_id.clone());
        let hold_rx = set.handle.submit(req).unwrap();
        gate.await_arrivals(1);

        // Fill shard 0's queue (depth 1) with a sticky request.
        let mut req = ClassifyRequest::new(img.clone());
        req.request_id = Some(sticky_id_for(0, 2, "fill"));
        let fill_rx = set.handle.submit(req).unwrap();

        // Third sticky-to-shard-0 request: queue full.  With spill it runs
        // on shard 1 (which is idle); without it the submit fails fast.
        let mut req = ClassifyRequest::new(img.clone());
        req.request_id = Some(sticky_id_for(0, 2, "probe"));
        if spill {
            let resp = set.handle.submit_blocking(req).unwrap();
            assert_eq!(resp.shard, Some(1), "must spill to the next-best shard");
        } else {
            let err = set.handle.submit(req).err().expect("must be QUEUE_FULL");
            assert_eq!(err.code, ErrorCode::QueueFull);
            // The failed submit must not leak gauges on either shard.
            assert_eq!(set.handle.shard_metrics(0).snapshot().queue_depth, 1);
            assert_eq!(set.handle.shard_metrics(1).snapshot().queue_depth, 0);
            assert_eq!(set.handle.shard_metrics(1).snapshot().in_flight, 0);
        }

        // Release the parked worker; the held and queued jobs complete on
        // shard 0.
        gate.release();
        assert_eq!(hold_rx.recv().unwrap().unwrap().shard, Some(0));
        assert_eq!(fill_rx.recv().unwrap().unwrap().shard, Some(0));
        // All gauges return to zero once idle.
        for s in 0..2 {
            let snap = set.handle.shard_metrics(s).snapshot();
            assert_eq!(snap.queue_depth, 0, "shard {s} queue_depth leaked");
            assert_eq!(snap.in_flight, 0, "shard {s} in_flight leaked");
        }
        set.shutdown();
    }
}

/// Panic-injection: the worker panic fails the carrying request with
/// INTERNAL, marks the shard unhealthy (observable *before* the failure
/// reaches the caller), keeps the other shards serving, restarts, and
/// rejoins the rotation with bitwise-identical behaviour.
#[test]
fn panicked_shard_goes_unhealthy_restarts_and_rejoins() {
    let gate = Gate::new();
    let c = cfg(Backend::FeatureCount, Engine::Interp, 2, RoutePolicy::RoundRobin);
    let (images, img_len) = workload(&c, 1, 77);
    let img = images[..img_len].to_vec();
    let set = ShardSet::start_with_hooks(
        &c,
        ShardHooks {
            panic_on: Some("boom".into()),
            restart_gate: Some(std::sync::Arc::clone(&gate)),
            ..Default::default()
        },
    )
    .unwrap();

    // t0 -> shard 0, t1 -> shard 1: record shard 0's answer for the
    // post-restart determinism check.
    let before = set.handle.classify_blocking(img.clone()).unwrap();
    assert_eq!(before.shard, Some(0));
    assert_eq!(
        set.handle.classify_blocking(img.clone()).unwrap().shard,
        Some(1)
    );
    assert!(!set.handle.health().degraded);

    // t2 -> shard 0 carries the injected panic: the caller gets a
    // structured INTERNAL failure, never a hang, and by the time it sees
    // the failure the deployment already reports degraded.
    let mut req = ClassifyRequest::new(img.clone());
    req.request_id = Some("boom".into());
    let err = set.handle.submit_blocking(req).err().expect("must fail");
    assert_eq!(err.code, ErrorCode::Internal);
    let health = set.handle.health();
    assert!(health.degraded, "unhealthy must be visible at failure time");
    assert!(!health.shards[0].healthy);
    assert!(health.shards[1].healthy);
    assert_eq!(set.handle.shard_metrics(0).snapshot().restarts, 1);

    // The restarting worker is parked on the gate: the degraded window is
    // held open while we assert routing avoids the down shard.
    gate.await_arrivals(1);
    let resp = set.handle.classify_blocking(img.clone()).unwrap();
    assert_eq!(resp.shard, Some(1), "router must skip the unhealthy shard");
    assert!(set.handle.health().degraded);

    // Release the restart; recovery is signalled through the gate, so
    // "recovered" is awaited, not polled.
    gate.release();
    gate.await_arrivals(2);
    assert!(!set.handle.health().degraded, "shard must recover");
    assert!(set.handle.shard_healthy(0));

    // The rotation includes shard 0 again, and the rebuilt pipeline is
    // deterministic: same image, same answer as before the panic.
    let mut shards_seen = std::collections::BTreeSet::new();
    let mut after_shard0 = None;
    for _ in 0..4 {
        let resp = set.handle.classify_blocking(img.clone()).unwrap();
        if resp.shard == Some(0) {
            after_shard0 = Some(resp.clone());
        }
        shards_seen.insert(resp.shard.unwrap());
    }
    assert_eq!(
        shards_seen.into_iter().collect::<Vec<_>>(),
        vec![0, 1],
        "restarted shard must rejoin the rotation"
    );
    let after = after_shard0.expect("shard 0 served post-restart");
    assert_eq!(after.predictions, before.predictions);
    assert_eq!(after.energy, before.energy);

    // Gauge regression: after every response resolved, nothing leaks.
    for s in 0..2 {
        let snap = set.handle.shard_metrics(s).snapshot();
        assert_eq!(snap.queue_depth, 0, "shard {s} queue_depth leaked");
        assert_eq!(snap.in_flight, 0, "shard {s} in_flight leaked");
    }
    set.shutdown();
}

/// Gauge-drift regression (ROADMAP satellite): a panicked shard's queued
/// jobs are failed with INTERNAL during the drain — not dropped, not
/// hung — and `queue_depth`/`in_flight` return to zero once idle.
#[test]
fn panic_drain_fails_queued_jobs_and_zeroes_gauges() {
    let hold_gate = Gate::new();
    let restart_gate = Gate::new();
    let mut c = cfg(Backend::FeatureCount, Engine::Interp, 1, RoutePolicy::RoundRobin);
    c.batch.max_batch = 1;
    c.batch.queue_depth = 8;
    let (images, img_len) = workload(&c, 1, 31);
    let img = images[..img_len].to_vec();
    let set = ShardSet::start_with_hooks(
        &c,
        ShardHooks {
            panic_on: Some("boom".into()),
            hold: Some(("hold".into(), std::sync::Arc::clone(&hold_gate))),
            restart_gate: Some(std::sync::Arc::clone(&restart_gate)),
            ..Default::default()
        },
    )
    .unwrap();

    // Park the worker, then queue: the panic request plus three innocent
    // bystanders behind it.
    let mut req = ClassifyRequest::new(img.clone());
    req.request_id = Some("hold".into());
    let hold_rx = set.handle.submit(req).unwrap();
    hold_gate.await_arrivals(1);
    let mut req = ClassifyRequest::new(img.clone());
    req.request_id = Some("boom".into());
    let boom_rx = set.handle.submit(req).unwrap();
    let bystanders: Vec<_> = (0..3)
        .map(|_| set.handle.submit(ClassifyRequest::new(img.clone())).unwrap())
        .collect();
    assert_eq!(set.handle.shard_metrics(0).snapshot().queue_depth, 4);
    assert_eq!(set.handle.shard_metrics(0).snapshot().in_flight, 5);

    // Run: the held job completes, the panic batch fails INTERNAL, and the
    // drain fails every queued bystander with INTERNAL (re-queueing would
    // need request replay semantics the API does not promise; failing fast
    // with a structured error is the documented contract).
    hold_gate.release();
    assert!(hold_rx.recv().unwrap().is_ok());
    assert_eq!(
        boom_rx.recv().unwrap().err().map(|e| e.code),
        Some(ErrorCode::Internal)
    );
    for rx in bystanders {
        assert_eq!(
            rx.recv().unwrap().err().map(|e| e.code),
            Some(ErrorCode::Internal),
            "queued job must fail fast during the drain, not hang"
        );
    }

    // Every waiter resolved => the gauges are exactly zero (no sleeps: the
    // worker decrements before it answers, so resolution implies the
    // accounting is done), while the restart is still parked.
    restart_gate.await_arrivals(1);
    let snap = set.handle.shard_metrics(0).snapshot();
    assert_eq!(snap.queue_depth, 0, "queue_depth leaked across the panic");
    assert_eq!(snap.in_flight, 0, "in_flight leaked across the panic");
    assert_eq!(snap.responses, 1);
    assert_eq!(snap.errors, 4);
    assert_eq!(snap.restarts, 1);

    // Single-shard deployment mid-restart: no healthy shard, so submits
    // shed load with QUEUE_FULL rather than queueing into a dead worker.
    // The shed submit is a *router* rejection: it shows up in the
    // deployment aggregate (requests/errors) and the dedicated counter,
    // never in any shard's own series.
    let err = set
        .handle
        .submit(ClassifyRequest::new(img.clone()))
        .err()
        .expect("no healthy shard");
    assert_eq!(err.code, ErrorCode::QueueFull);
    assert_eq!(set.handle.router_rejections(), 1);
    assert_eq!(set.handle.shard_metrics(0).snapshot().errors, 4);
    assert_eq!(set.handle.snapshot().errors, 5, "aggregate = shard + router");

    restart_gate.release();
    restart_gate.await_arrivals(2);
    let resp = set.handle.classify_blocking(img).unwrap();
    assert_eq!(resp.shard, Some(0));
    let snap = set.handle.shard_metrics(0).snapshot();
    assert_eq!(snap.queue_depth, 0);
    assert_eq!(snap.in_flight, 0);
    set.shutdown();
}

/// Per-shard Prometheus series: `/metrics`-payload rendering carries
/// `shard`-labelled queue-depth / in-flight / served / restarts gauges for
/// every shard, alongside the aggregate series.
#[test]
fn prometheus_text_carries_shard_labels() {
    let c = cfg(Backend::FeatureCount, Engine::Interp, 2, RoutePolicy::RoundRobin);
    let (images, img_len) = workload(&c, 3, 11);
    let set = ShardSet::start(&c).unwrap();
    for i in 0..3 {
        set.handle
            .classify_blocking(images[i * img_len..(i + 1) * img_len].to_vec())
            .unwrap();
    }
    let text = set.handle.prometheus_text();
    for needle in [
        "hec_requests_total 3",         // aggregate over both shards
        "hec_shard_queue_depth{shard=\"0\"} 0",
        "hec_shard_queue_depth{shard=\"1\"} 0",
        "hec_shard_in_flight{shard=\"0\"} 0",
        "hec_shard_served_total{shard=\"0\"} 2", // requests 0 and 2
        "hec_shard_served_total{shard=\"1\"} 1",
        "hec_shard_restarts_total{shard=\"0\"} 0",
        "hec_shard_healthy{shard=\"1\"} 1",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    set.shutdown();
}

/// The pure routing planner is re-exported for operational tooling; pin
/// the cross-crate surface (the in-crate unit tests cover the semantics).
#[test]
fn plan_route_is_usable_from_the_public_api() {
    assert_eq!(
        plan_route(RoutePolicy::RoundRobin, 4, None, &[0, 0, 0], &[true; 3], false),
        vec![1]
    );
    assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
}

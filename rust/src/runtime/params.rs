//! Parameter sidecar loader.
//!
//! Exported entry points take their weights as runtime arguments (the
//! mlir->XLA conversion in the build toolchain corrupts large baked
//! constants — see `python/compile/aot.py::export_parameterized`).  Each
//! parameterized artifact `<name>.hlo.txt` ships with:
//!
//! * `<name>.params.json` — manifest: array shapes in argument order;
//! * `<name>.params.bin`  — the raw little-endian f32 payload.
//!
//! The runtime uploads every array once as a device-resident PJRT buffer at
//! load time and appends the buffers to each execute call.

use std::path::Path;

use crate::error::{Error, Result};
use crate::jsonlite::{self, Value};

/// One parameter array: shape + f32 data.
#[derive(Debug, Clone)]
pub struct ParamArray {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl ParamArray {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Load the `<prefix>.params.{json,bin}` sidecar pair.  Returns an empty
/// vector when no manifest exists (constant-free artifacts like the
/// matchers).
pub fn load_params(dir: &Path, name: &str) -> Result<Vec<ParamArray>> {
    let manifest_path = dir.join(format!("{name}.params.json"));
    if !manifest_path.is_file() {
        return Ok(Vec::new());
    }
    let manifest = jsonlite::parse(&std::fs::read_to_string(&manifest_path)?)?;
    let bin = std::fs::read(dir.join(format!("{name}.params.bin")))?;
    if bin.len() % 4 != 0 {
        return Err(Error::Artifact(format!(
            "{name}.params.bin length {} is not a multiple of 4",
            bin.len()
        )));
    }
    let floats: Vec<f32> = bin
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    let arrays = manifest
        .get("arrays")
        .and_then(Value::as_array)
        .ok_or_else(|| Error::Schema(format!("{name}.params.json: missing 'arrays'")))?;
    let total = manifest
        .get("total")
        .and_then(Value::as_usize)
        .unwrap_or(floats.len());
    if total != floats.len() {
        return Err(Error::Artifact(format!(
            "{name}.params.bin holds {} floats, manifest says {total}",
            floats.len()
        )));
    }

    let mut out = Vec::with_capacity(arrays.len());
    for (i, a) in arrays.iter().enumerate() {
        let shape: Vec<usize> = a
            .get("shape")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::Schema(format!("{name}: array {i} missing shape")))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Schema(format!("{name}: bad dim in array {i}")))
            })
            .collect::<Result<_>>()?;
        let offset = a
            .get("offset")
            .and_then(Value::as_usize)
            .ok_or_else(|| Error::Schema(format!("{name}: array {i} missing offset")))?;
        let len: usize = shape.iter().product();
        if offset + len > floats.len() {
            return Err(Error::Artifact(format!(
                "{name}: array {i} spans past the end of the payload"
            )));
        }
        out.push(ParamArray {
            shape,
            data: floats[offset..offset + len].to_vec(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("hec-params-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn write_sidecar(dir: &Path, name: &str, arrays: &[(&[usize], &[f32])]) {
        let mut bin: Vec<u8> = Vec::new();
        let mut manifest = String::from("{\"arrays\":[");
        let mut offset = 0usize;
        for (i, (shape, data)) in arrays.iter().enumerate() {
            if i > 0 {
                manifest.push(',');
            }
            manifest.push_str(&format!(
                "{{\"shape\":{:?},\"offset\":{offset}}}",
                shape.to_vec()
            ));
            for v in *data {
                bin.extend_from_slice(&v.to_le_bytes());
            }
            offset += data.len();
        }
        manifest.push_str(&format!("],\"total\":{offset}}}"));
        std::fs::write(dir.join(format!("{name}.params.json")), manifest).unwrap();
        std::fs::write(dir.join(format!("{name}.params.bin")), bin).unwrap();
    }

    #[test]
    fn missing_manifest_is_empty() {
        let dir = scratch("none");
        assert!(load_params(&dir, "nope").unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_two_arrays() {
        let dir = scratch("two");
        write_sidecar(
            &dir,
            "m",
            &[(&[2, 3], &[1., 2., 3., 4., 5., 6.]), (&[2], &[7., 8.])],
        );
        let ps = load_params(&dir, "m").unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].shape, vec![2, 3]);
        assert_eq!(ps[0].data, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(ps[1].data, vec![7., 8.]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_payload_is_error() {
        let dir = scratch("trunc");
        write_sidecar(&dir, "m", &[(&[4], &[1., 2., 3., 4.])]);
        // Chop the bin file.
        let bin_path = dir.join("m.params.bin");
        let bin = std::fs::read(&bin_path).unwrap();
        std::fs::write(&bin_path, &bin[..8]).unwrap();
        assert!(load_params(&dir, "m").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! The pluggable back-end matching seam — the [`MatchingBackend`] trait and
//! its four variants, mirroring the front-end's `runtime::FrontEnd` seam.
//!
//! The paper deploys one fixed back-end: the RRAM-CMOS TXL-ACAM template
//! matcher.  PAPERS.md names two drop-in alternatives from the same group —
//! the RBF-neuron analogue classifier (arxiv 2606.14739) and the 9T4R ACAM
//! cell (arxiv 2410.03414) — and the digital Eq. 8 matcher has always been
//! the ladder's fallback special case.  This module makes all four
//! first-class, selectable variants:
//!
//! | variant     | scoring kernel                           | search energy / cell | re-program / cell |
//! |-------------|------------------------------------------|----------------------|-------------------|
//! | `acam`      | TXL 6T4R/3T1R matchline + WTA (default)  | 185 fJ               | 80 pJ             |
//! | `acam-9t4r` | 9T4R graded matchline + WTA              | 278 fJ               | 80 pJ             |
//! | `rbf`       | Gaussian RBF neuron over Hamming distance| 92 fJ                | 40 pJ             |
//! | `digital`   | packed popcount Eq. 8 (exact reference)  | 185 fJ envelope      | free              |
//!
//! The contract every unit implements: score/rank a binarised query,
//! health-probe against the digital reference, (re-)program from a template
//! set with a per-variant energy constant, absorb injected faults, and
//! report per-classification energy.  The pipeline owns the *shared*
//! serving state — the WTA/sense RNG stream, the variability corner, the
//! re-program seed schedule — and passes it in, so the default `acam`
//! variant replays the pre-seam instruction sequence bit for bit
//! (predictions, RNG draws, energy figures, wire bytes).

use std::str::FromStr;

use crate::acam::cell::CellKind;
use crate::acam::program::{binary_query_voltages, program_array, WindowMode};
use crate::acam::{wta, AcamArray, ArrayConfig, Variability};
use crate::energy::constants::{
    ACAM_9T4R_CELL_ENERGY_FJ, RBF_CELL_ENERGY_FJ, RBF_PROGRAM_CELL_PJ, RRAM_PROGRAM_CELL_PJ,
};
use crate::energy::EnergyModel;
use crate::error::Error;
use crate::faults::{FaultInjector, FaultKind, StuckSet};
use crate::matching;
use crate::templates::TemplateSet;

/// The selectable back-end variant (`--backend`, `backend.variant`,
/// `HEC_BACKEND`).  Distinct from [`crate::config::Backend`], which routes
/// *requests* (acam / fc / sim / softmax): the variant decides what
/// hardware an `acam`-routed request lands on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendVariant {
    /// The paper's TXL-ACAM array (6T4R or 3T1R cells) — the default,
    /// pinned bitwise-identical to pre-seam serving.
    Acam,
    /// The 9T4R analogue ACAM cell (arxiv 2410.03414): graded matchline
    /// currents, higher per-cell energy.
    Acam9T4R,
    /// The RBF-neuron classifier (arxiv 2606.14739): Gaussian bump over
    /// Hamming distance, cheaper cells, 2-RRAM synapses.
    Rbf,
    /// The exact digital Eq. 8 matcher — the ladder's fallback path made
    /// deployable in its own right.
    Digital,
}

impl BackendVariant {
    pub fn name(&self) -> &'static str {
        match self {
            BackendVariant::Acam => "acam",
            BackendVariant::Acam9T4R => "acam-9t4r",
            BackendVariant::Rbf => "rbf",
            BackendVariant::Digital => "digital",
        }
    }

    /// Whether the variant models analogue hardware that decays — i.e.
    /// whether the canary/degradation ladder has anything to watch.  The
    /// digital variant *is* the ladder's reference, so arming canaries on
    /// it would only ever agree with itself.
    pub fn analogue(&self) -> bool {
        !matches!(self, BackendVariant::Digital)
    }

    /// All variants, in flag order (bench + CI matrix).
    pub const ALL: [BackendVariant; 4] = [
        BackendVariant::Acam,
        BackendVariant::Acam9T4R,
        BackendVariant::Rbf,
        BackendVariant::Digital,
    ];
}

impl FromStr for BackendVariant {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self, Error> {
        match s {
            "acam" => Ok(BackendVariant::Acam),
            "acam-9t4r" | "acam_9t4r" | "9t4r" => Ok(BackendVariant::Acam9T4R),
            "rbf" => Ok(BackendVariant::Rbf),
            "digital" => Ok(BackendVariant::Digital),
            other => Err(Error::Config(format!(
                "unknown backend variant '{other}' (expected acam | acam-9t4r | rbf | digital)"
            ))),
        }
    }
}

/// Ranked classification outcome of one back-end search.
pub struct ScoreOutcome {
    /// `(class, score)` descending, truncated to the requested k.
    pub ranked: Vec<(usize, f64)>,
    /// Back-end search energy (nJ).
    pub energy_nj: f64,
}

/// One canary probe's evidence, before the pipeline compares it with the
/// digital reference.
pub struct ProbeOutcome {
    /// The variant's top-1 class for the probe.
    pub top_class: usize,
    /// The strongest raw row similarity (the analogue match margin input).
    pub top_similarity: f64,
    /// Search energy spent on the probe (nJ).
    pub energy_nj: f64,
}

/// The back-end seam.  One unit == one programmed matching engine bound to
/// a template set; the pipeline keeps a unit per store binding.
///
/// Shared serving state (the WTA RNG stream, the active variability
/// corner, the energy model) stays in the pipeline and is passed per call —
/// that is what pins the default variant's RNG draw order to the pre-seam
/// code exactly.
pub trait MatchingBackend: Send {
    fn variant(&self) -> BackendVariant;

    /// Score an already-binarised query: ranked top-k `(class, score)` plus
    /// the search energy.
    fn score(
        &mut self,
        bits: &[u8],
        set: &TemplateSet,
        num_classes: usize,
        k: usize,
        energy: &EnergyModel,
        var: &Variability,
        rng: &mut crate::rng::Rng,
    ) -> ScoreOutcome;

    /// Evaluate one canary probe (same kernel as [`Self::score`], plus the
    /// raw top-row similarity the ladder's margin tracks).
    fn probe(
        &mut self,
        bits: &[u8],
        set: &TemplateSet,
        num_classes: usize,
        energy: &EnergyModel,
        var: &Variability,
        rng: &mut crate::rng::Rng,
    ) -> ProbeOutcome;

    /// Re-program the unit from `set` at the `var` corner with a
    /// deterministic seed (clears drift/read-noise escalations; the caller
    /// re-applies sticky stuck sets).
    fn reprogram(&mut self, set: &TemplateSet, var: &Variability, seed: u64);

    /// Energy (nJ) one full (re-)programming of `n_templates x n_features`
    /// cells costs on this variant.
    fn reprogram_nj(&self, n_templates: u64, n_features: u64) -> f64;

    /// Build a sibling unit of the same variant/periphery programmed from a
    /// different template set (tenant store bindings).
    fn spawn(&self, set: &TemplateSet, var: &Variability, seed: u64) -> Box<dyn MatchingBackend>;

    /// Absorb one injected fault (stall faults are the worker loop's
    /// business and are ignored by every unit).
    fn apply_fault(&mut self, kind: &FaultKind, inj: &mut FaultInjector);

    /// Re-apply sticky stuck-cell sets after a re-programming; returns the
    /// number of cells stuck.
    fn apply_sticky(&mut self, sets: &[StuckSet]) -> usize;

    /// Static full-match headroom at the design point (1.0 where the
    /// concept does not apply).
    fn headroom(&self) -> f64;
}

/// Build a unit of `variant` programmed from `set`.  `cell_kind` selects
/// the TXL pixel for the `acam` variant (the 9T4R variant always uses its
/// own cell).
pub fn build_unit(
    variant: BackendVariant,
    cell_kind: CellKind,
    set: &TemplateSet,
    var: &Variability,
    seed: u64,
) -> Box<dyn MatchingBackend> {
    match variant {
        BackendVariant::Acam => Box::new(AcamUnit::build(
            BackendVariant::Acam,
            ArrayConfig {
                kind: cell_kind,
                ..Default::default()
            },
            set,
            var,
            seed,
        )),
        BackendVariant::Acam9T4R => Box::new(AcamUnit::build(
            BackendVariant::Acam9T4R,
            ArrayConfig {
                kind: CellKind::Analogue9T4R,
                cell_energy_fj: ACAM_9T4R_CELL_ENERGY_FJ,
                ..Default::default()
            },
            set,
            var,
            seed,
        )),
        BackendVariant::Rbf => Box::new(RbfUnit::build(set, var, seed)),
        BackendVariant::Digital => Box::new(DigitalUnit),
    }
}

// ---------------------------------------------------------------------------
// ACAM family: the TXL array (default) and the 9T4R graded array.
// ---------------------------------------------------------------------------

/// An [`AcamArray`] behind the seam.  `variant` distinguishes the default
/// TXL array from the 9T4R build (same array machinery, different cell
/// model + energy constant carried in the `ArrayConfig`).
struct AcamUnit {
    variant: BackendVariant,
    arr: AcamArray,
}

impl AcamUnit {
    fn build(
        variant: BackendVariant,
        config: ArrayConfig,
        set: &TemplateSet,
        var: &Variability,
        seed: u64,
    ) -> Self {
        AcamUnit {
            variant,
            arr: program_array(set, WindowMode::Binary, config, var.clone(), seed),
        }
    }
}

impl MatchingBackend for AcamUnit {
    fn variant(&self) -> BackendVariant {
        self.variant
    }

    fn score(
        &mut self,
        bits: &[u8],
        set: &TemplateSet,
        num_classes: usize,
        k: usize,
        _energy: &EnergyModel,
        var: &Variability,
        rng: &mut crate::rng::Rng,
    ) -> ScoreOutcome {
        let search = self.arr.search(&binary_query_voltages(bits));
        let mut ranked = wta::rank_classes(&search.similarity, &set.class_of, num_classes, var, rng);
        ranked.truncate(k);
        ScoreOutcome {
            ranked,
            energy_nj: search.energy_nj,
        }
    }

    fn probe(
        &mut self,
        bits: &[u8],
        set: &TemplateSet,
        num_classes: usize,
        _energy: &EnergyModel,
        var: &Variability,
        rng: &mut crate::rng::Rng,
    ) -> ProbeOutcome {
        let search = self.arr.search(&binary_query_voltages(bits));
        let ranked = wta::rank_classes(&search.similarity, &set.class_of, num_classes, var, rng);
        ProbeOutcome {
            top_class: ranked[0].0,
            top_similarity: search.similarity.iter().cloned().fold(0.0, f64::max),
            energy_nj: search.energy_nj,
        }
    }

    fn reprogram(&mut self, set: &TemplateSet, var: &Variability, seed: u64) {
        let config = self.arr.config.clone();
        self.arr = program_array(set, WindowMode::Binary, config, var.clone(), seed);
    }

    fn reprogram_nj(&self, n_templates: u64, n_features: u64) -> f64 {
        (n_templates * n_features) as f64 * RRAM_PROGRAM_CELL_PJ * 1e-3
    }

    fn spawn(&self, set: &TemplateSet, var: &Variability, seed: u64) -> Box<dyn MatchingBackend> {
        Box::new(AcamUnit {
            variant: self.variant,
            arr: program_array(set, WindowMode::Binary, self.arr.config.clone(), var.clone(), seed),
        })
    }

    fn apply_fault(&mut self, kind: &FaultKind, inj: &mut FaultInjector) {
        match kind {
            FaultKind::Drift { level } => {
                self.arr.variability = Variability::at_level(*level);
            }
            FaultKind::ReadNoise { sigma } => {
                self.arr.variability.read_sigma = *sigma;
            }
            FaultKind::StuckCells { fraction, g } => {
                let set = inj.materialize_stuck(self.arr.num_rows(), self.arr.width(), *fraction, *g);
                self.arr.stick_cells(&set.cells, set.g);
            }
            FaultKind::Stall { .. } => {}
        }
    }

    fn apply_sticky(&mut self, sets: &[StuckSet]) -> usize {
        sets.iter().map(|s| self.arr.stick_cells(&s.cells, s.g)).sum()
    }

    fn headroom(&self) -> f64 {
        self.arr.full_match_headroom()
    }
}

// ---------------------------------------------------------------------------
// RBF-neuron variant (arxiv 2606.14739).
// ---------------------------------------------------------------------------

/// Gaussian width of the RBF bump, as a fraction of the feature width:
/// `sigma = n_features * RBF_SIGMA_FRACTION` Hamming units.  At 784
/// features sigma is 98 — templates a full class-distance away (hundreds of
/// mismatching bits) score essentially zero while near matches keep
/// meaningful separation, mirroring the published neuron's tuning range.
pub const RBF_SIGMA_FRACTION: f64 = 0.125;

/// The RBF-neuron classifier: one neuron per template row, each computing
/// `exp(-d^2 / (2 sigma^2))` over the (programming-weighted) Hamming
/// distance `d` between the query and its stored centre.
///
/// Behavioural analogue model:
/// * programming variability perturbs each synapse's mismatch weight
///   multiplicatively (log-normal, like the RRAM conductance spread);
/// * read noise multiplies each neuron's bump output per evaluation,
///   drawn from the unit's own RNG stream (mirroring the array-owned
///   read-noise stream of the ACAM sim);
/// * a stuck synapse always reports mismatch — its contribution to `d`
///   becomes constant, degrading that neuron's peak score;
/// * the shared WTA stage (offset noise from the *pipeline* RNG) ranks the
///   per-neuron scores, exactly as it ranks ACAM matchline voltages.
struct RbfUnit {
    /// Stored centres, row-major `rows x width` (copied at program time).
    centres: Vec<u8>,
    /// Per-synapse mismatch weights (1.0 ideal; log-normal programming
    /// spread otherwise).
    weights: Vec<f64>,
    stuck: Vec<bool>,
    rows: usize,
    width: usize,
    /// Gaussian width in Hamming units.
    sigma: f64,
    /// The unit's read-noise corner (updated by drift/read-noise faults).
    var: Variability,
    /// Unit-owned RNG: consumed at programming, then per evaluation when
    /// read noise is active — never touches the pipeline's WTA stream.
    rng: crate::rng::Rng,
}

impl RbfUnit {
    fn build(set: &TemplateSet, var: &Variability, seed: u64) -> Self {
        let rows = set.num_templates();
        let width = set.num_features();
        let mut unit = RbfUnit {
            centres: Vec::new(),
            weights: Vec::new(),
            stuck: Vec::new(),
            rows,
            width,
            sigma: (width as f64 * RBF_SIGMA_FRACTION).max(1.0),
            var: var.clone(),
            rng: crate::rng::Rng::new(seed),
        };
        unit.program(set, var, seed);
        unit
    }

    fn program(&mut self, set: &TemplateSet, var: &Variability, seed: u64) {
        self.rows = set.num_templates();
        self.width = set.num_features();
        self.sigma = (self.width as f64 * RBF_SIGMA_FRACTION).max(1.0);
        self.var = var.clone();
        self.rng = crate::rng::Rng::new(seed);
        self.centres = set.templates.iter().flatten().copied().collect();
        self.stuck = vec![false; self.rows * self.width];
        self.weights = if var.program_sigma > 0.0 {
            (0..self.rows * self.width)
                .map(|_| self.rng.normal(0.0, var.program_sigma).exp())
                .collect()
        } else {
            vec![1.0; self.rows * self.width]
        };
    }

    /// Per-neuron Gaussian scores for one query (consumes the unit RNG for
    /// read noise when active).
    fn neuron_scores(&mut self, bits: &[u8]) -> Vec<f64> {
        let mut scores = Vec::with_capacity(self.rows);
        for r in 0..self.rows {
            let base = r * self.width;
            let mut d = 0f64;
            for j in 0..self.width {
                let mismatch = self.stuck[base + j] || self.centres[base + j] != bits[j];
                if mismatch {
                    d += self.weights[base + j];
                }
            }
            let mut s = (-d * d / (2.0 * self.sigma * self.sigma)).exp();
            if self.var.read_sigma > 0.0 {
                s *= self.rng.normal(0.0, self.var.read_sigma).exp();
            }
            scores.push(s);
        }
        scores
    }

    fn energy_nj(&self) -> f64 {
        (self.rows * self.width) as f64 * RBF_CELL_ENERGY_FJ * 1e-6
    }
}

impl MatchingBackend for RbfUnit {
    fn variant(&self) -> BackendVariant {
        BackendVariant::Rbf
    }

    fn score(
        &mut self,
        bits: &[u8],
        set: &TemplateSet,
        num_classes: usize,
        k: usize,
        _energy: &EnergyModel,
        var: &Variability,
        rng: &mut crate::rng::Rng,
    ) -> ScoreOutcome {
        let scores = self.neuron_scores(bits);
        let mut ranked = wta::rank_classes(&scores, &set.class_of, num_classes, var, rng);
        ranked.truncate(k);
        ScoreOutcome {
            ranked,
            energy_nj: self.energy_nj(),
        }
    }

    fn probe(
        &mut self,
        bits: &[u8],
        set: &TemplateSet,
        num_classes: usize,
        _energy: &EnergyModel,
        var: &Variability,
        rng: &mut crate::rng::Rng,
    ) -> ProbeOutcome {
        let scores = self.neuron_scores(bits);
        let ranked = wta::rank_classes(&scores, &set.class_of, num_classes, var, rng);
        ProbeOutcome {
            top_class: ranked[0].0,
            top_similarity: scores.iter().cloned().fold(0.0, f64::max),
            energy_nj: self.energy_nj(),
        }
    }

    fn reprogram(&mut self, set: &TemplateSet, var: &Variability, seed: u64) {
        self.program(set, var, seed);
    }

    fn reprogram_nj(&self, n_templates: u64, n_features: u64) -> f64 {
        (n_templates * n_features) as f64 * RBF_PROGRAM_CELL_PJ * 1e-3
    }

    fn spawn(&self, set: &TemplateSet, var: &Variability, seed: u64) -> Box<dyn MatchingBackend> {
        Box::new(RbfUnit::build(set, var, seed))
    }

    fn apply_fault(&mut self, kind: &FaultKind, inj: &mut FaultInjector) {
        match kind {
            FaultKind::Drift { level } => {
                self.var = Variability::at_level(*level);
            }
            FaultKind::ReadNoise { sigma } => {
                self.var.read_sigma = *sigma;
            }
            FaultKind::StuckCells { fraction, g } => {
                let set = inj.materialize_stuck(self.rows, self.width, *fraction, *g);
                self.apply_sticky(std::slice::from_ref(&set));
            }
            FaultKind::Stall { .. } => {}
        }
    }

    fn apply_sticky(&mut self, sets: &[StuckSet]) -> usize {
        let mut stuck = 0;
        for s in sets {
            for &(r, c) in &s.cells {
                if r < self.rows && c < self.width {
                    self.stuck[r * self.width + c] = true;
                    stuck += 1;
                }
            }
        }
        stuck
    }

    fn headroom(&self) -> f64 {
        1.0
    }
}

// ---------------------------------------------------------------------------
// Digital variant: the exact Eq. 8 reference as a deployable back-end.
// ---------------------------------------------------------------------------

/// The packed popcount matcher — bitwise-identical to the degradation
/// ladder's `digital_fallback` serving path, costed at the same digital
/// envelope.  Stateless: templates live in the store, nothing to program,
/// nothing that decays (so the canary ladder never arms on it).
struct DigitalUnit;

impl MatchingBackend for DigitalUnit {
    fn variant(&self) -> BackendVariant {
        BackendVariant::Digital
    }

    fn score(
        &mut self,
        bits: &[u8],
        set: &TemplateSet,
        num_classes: usize,
        k: usize,
        energy: &EnergyModel,
        _var: &Variability,
        _rng: &mut crate::rng::Rng,
    ) -> ScoreOutcome {
        let top = matching::classify_feature_count_topk(bits, set, num_classes, k);
        ScoreOutcome {
            ranked: top.into_iter().map(|(c, s)| (c, s as f64)).collect(),
            energy_nj: energy.backend_nj(set.num_templates() as u64, set.num_features() as u64),
        }
    }

    fn probe(
        &mut self,
        bits: &[u8],
        set: &TemplateSet,
        num_classes: usize,
        energy: &EnergyModel,
        _var: &Variability,
        _rng: &mut crate::rng::Rng,
    ) -> ProbeOutcome {
        let top = matching::classify_feature_count_topk(bits, set, num_classes, 1);
        ProbeOutcome {
            top_class: top[0].0,
            top_similarity: top[0].1 as f64 / set.num_features().max(1) as f64,
            energy_nj: energy.backend_nj(set.num_templates() as u64, set.num_features() as u64),
        }
    }

    fn reprogram(&mut self, _set: &TemplateSet, _var: &Variability, _seed: u64) {}

    fn reprogram_nj(&self, _n_templates: u64, _n_features: u64) -> f64 {
        0.0
    }

    fn spawn(&self, _set: &TemplateSet, _var: &Variability, _seed: u64) -> Box<dyn MatchingBackend> {
        Box::new(DigitalUnit)
    }

    fn apply_fault(&mut self, _kind: &FaultKind, _inj: &mut FaultInjector) {}

    fn apply_sticky(&mut self, _sets: &[StuckSet]) -> usize {
        0
    }

    fn headroom(&self) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::templates::TemplateStore;

    fn toy_set() -> TemplateStore {
        // 4 classes, 16 features, clearly separated centres.
        let classes = 4usize;
        let nf = 16usize;
        let per_class = 6usize;
        let mut rng = crate::rng::Rng::new(5);
        let n = classes * per_class;
        let mut feats = vec![0f32; n * nf];
        let mut labels = vec![0usize; n];
        for i in 0..n {
            let c = i % classes;
            labels[i] = c;
            for j in 0..nf {
                let base = if j % classes == c { 1.0 } else { 0.0 };
                feats[i * nf + j] = base + rng.range(-0.1, 0.1) as f32;
            }
        }
        TemplateStore::from_features(&feats, &labels, nf, classes, 3).unwrap()
    }

    #[test]
    fn variant_names_parse_and_roundtrip() {
        for v in BackendVariant::ALL {
            assert_eq!(v.name().parse::<BackendVariant>().unwrap(), v);
        }
        assert_eq!("9t4r".parse::<BackendVariant>().unwrap(), BackendVariant::Acam9T4R);
        assert_eq!("acam_9t4r".parse::<BackendVariant>().unwrap(), BackendVariant::Acam9T4R);
        assert!("nope".parse::<BackendVariant>().is_err());
        assert!(BackendVariant::Acam.analogue());
        assert!(BackendVariant::Rbf.analogue());
        assert!(!BackendVariant::Digital.analogue());
    }

    #[test]
    fn every_variant_classifies_clean_templates_correctly() {
        let store = toy_set();
        let set = store.set(1).unwrap();
        let energy = EnergyModel::default();
        let ideal = Variability::ideal();
        for variant in BackendVariant::ALL {
            let mut unit = build_unit(variant, CellKind::Charging6T4R, set, &ideal, 42);
            let mut rng = crate::rng::Rng::new(0);
            for (t, &c) in set.templates.iter().zip(set.class_of.iter()) {
                let out = unit.score(t, set, store.num_classes, 2, &energy, &ideal, &mut rng);
                assert_eq!(out.ranked[0].0, c, "{} top-1 on its own template", variant.name());
                assert!(out.energy_nj >= 0.0);
                assert!(out.ranked.len() <= 2);
            }
        }
    }

    #[test]
    fn probe_agrees_with_digital_on_ideal_devices() {
        let store = toy_set();
        let set = store.set(1).unwrap();
        let energy = EnergyModel::default();
        let ideal = Variability::ideal();
        for variant in BackendVariant::ALL {
            let mut unit = build_unit(variant, CellKind::Charging6T4R, set, &ideal, 7);
            let mut rng = crate::rng::Rng::new(1);
            for t in &set.templates {
                let digital =
                    matching::classify_feature_count_topk(t, set, store.num_classes, 1)[0].0;
                let p = unit.probe(t, set, store.num_classes, &energy, &ideal, &mut rng);
                assert_eq!(p.top_class, digital, "{}", variant.name());
                assert!(p.top_similarity > 0.0);
            }
        }
    }

    #[test]
    fn per_variant_energy_constants_order() {
        let store = toy_set();
        let set = store.set(1).unwrap();
        let energy = EnergyModel::default();
        let ideal = Variability::ideal();
        let mut rng = crate::rng::Rng::new(2);
        let q = &set.templates[0];
        let nt = set.num_templates() as u64;
        let nf = set.num_features() as u64;
        let mut acam = build_unit(BackendVariant::Acam, CellKind::Charging6T4R, set, &ideal, 1);
        let mut a9 = build_unit(BackendVariant::Acam9T4R, CellKind::Charging6T4R, set, &ideal, 1);
        let mut rbf = build_unit(BackendVariant::Rbf, CellKind::Charging6T4R, set, &ideal, 1);
        let mut dig = build_unit(BackendVariant::Digital, CellKind::Charging6T4R, set, &ideal, 1);
        let e_acam = acam.score(q, set, 4, 1, &energy, &ideal, &mut rng).energy_nj;
        let e_a9 = a9.score(q, set, 4, 1, &energy, &ideal, &mut rng).energy_nj;
        let e_rbf = rbf.score(q, set, 4, 1, &energy, &ideal, &mut rng).energy_nj;
        let e_dig = dig.score(q, set, 4, 1, &energy, &ideal, &mut rng).energy_nj;
        // Search: 9T4R > acam == digital envelope > rbf.
        assert!(e_a9 > e_acam, "{e_a9} vs {e_acam}");
        assert!((e_dig - e_acam).abs() < 1e-12);
        assert!(e_rbf < e_acam);
        // Re-program: acam == 9t4r (4R pixels) > rbf (2R synapses) > digital (free).
        assert_eq!(acam.reprogram_nj(nt, nf), a9.reprogram_nj(nt, nf));
        assert!(rbf.reprogram_nj(nt, nf) < acam.reprogram_nj(nt, nf));
        assert!(rbf.reprogram_nj(nt, nf) > 0.0);
        assert_eq!(dig.reprogram_nj(nt, nf), 0.0);
    }

    #[test]
    fn rbf_stuck_synapses_degrade_peak_score() {
        let store = toy_set();
        let set = store.set(1).unwrap();
        let energy = EnergyModel::default();
        let ideal = Variability::ideal();
        let mut unit = build_unit(BackendVariant::Rbf, CellKind::Charging6T4R, set, &ideal, 9);
        let mut rng = crate::rng::Rng::new(3);
        let q = &set.templates[0];
        let clean = unit
            .probe(q, set, store.num_classes, &energy, &ideal, &mut rng)
            .top_similarity;
        let cells: Vec<(usize, usize)> = (0..set.num_features()).map(|c| (0, c)).collect();
        let stuck = unit.apply_sticky(&[StuckSet { cells, g: 1e-6 }]);
        assert_eq!(stuck, set.num_features());
        let degraded = unit
            .probe(q, set, store.num_classes, &energy, &ideal, &mut rng)
            .top_similarity;
        assert!(degraded < clean, "{degraded} vs {clean}");
    }

    #[test]
    fn reprogram_restores_rbf_after_faults() {
        let store = toy_set();
        let set = store.set(1).unwrap();
        let energy = EnergyModel::default();
        let ideal = Variability::ideal();
        let mut unit = build_unit(BackendVariant::Rbf, CellKind::Charging6T4R, set, &ideal, 9);
        let mut rng = crate::rng::Rng::new(4);
        let q = &set.templates[1];
        let clean = unit
            .probe(q, set, store.num_classes, &energy, &ideal, &mut rng)
            .top_similarity;
        let cells: Vec<(usize, usize)> = (0..set.num_features()).map(|c| (1, c)).collect();
        unit.apply_sticky(&[StuckSet { cells, g: 1e-6 }]);
        unit.reprogram(set, &ideal, 11);
        let restored = unit
            .probe(q, set, store.num_classes, &energy, &ideal, &mut rng)
            .top_similarity;
        assert_eq!(restored, clean);
    }
}

//! Table I reproduction: teacher vs student (± optimisations) — accuracy,
//! F1/precision/recall, parameters, MAC counts, compression ratios — plus
//! the measured front-end inference latency through the deployed execution
//! engine.
//!
//! Paper-vs-measured *shape* assertions: the student keeps a tiny fraction
//! of the teacher's parameters/MACs, optimisations close most of the
//! baseline gap, and the optimised student's effective MACs reflect the 80%
//! sparsity skip.

use hec::benchkit::{bench_for, paper_row, section};
use hec::config::{Backend, ServeConfig};
use hec::coordinator::Pipeline;
use hec::energy::constants;
use hec::runtime::Meta;
use std::time::Duration;

fn main() {
    if !std::path::Path::new("artifacts/meta.json").is_file() {
        println!("table1_model_perf: run `make artifacts` first");
        return;
    }
    let meta = Meta::load("artifacts").unwrap();
    let t1 = &meta.experiments.table1;

    section("Table I — accuracy (paper % vs measured, this testbed)");
    let rows = [
        ("teacher_color", constants::TEACHER_COLOR.accuracy),
        ("teacher_gray", constants::TEACHER_GRAY.accuracy),
        ("student_base", constants::STUDENT_BASE.accuracy),
        ("student_opt", constants::STUDENT_OPT.accuracy),
    ];
    for (name, paper) in rows {
        let m = &t1[name];
        paper_row(name, paper / 100.0, m.accuracy, "acc");
        println!(
            "    f1={:.4} precision={:.4} recall={:.4} params={} macs={}",
            m.f1, m.precision, m.recall, m.params, m.macs
        );
    }

    section("Table I — compression ratios (MACs vs teacher colour)");
    let tc = t1["teacher_color"].macs as f64;
    for name in ["teacher_gray", "student_base", "student_opt"] {
        let ratio = tc / t1[name].macs as f64;
        let paper_ratio = match name {
            "teacher_gray" => 1.01,
            "student_base" => 162.0,
            _ => 811.0,
        };
        paper_row(&format!("{name} compression"), paper_ratio, ratio, ":1");
    }

    // Shape assertions (who wins, roughly by how much).
    let acc = |n: &str| t1[n].accuracy;
    if acc("teacher_color") < acc("student_opt") {
        // Scale artifact: the CPU-trainable teacher is width-scaled far below
        // ResNet-50 and can lose to the student on the synthetic workload;
        // the paper-scale MAC/param ratios above are the reproduction target.
        println!("note: width-scaled teacher trails the student at this scale (see DESIGN.md)");
    }
    assert!(acc("teacher_color") > 0.5, "teacher must be well above chance");
    assert!(
        acc("student_opt") >= acc("student_base") - 0.02,
        "optimisations must not regress the student"
    );
    // The parameter-compression claim is asserted at paper scale (exact
    // constants); as-built the width-scaled teacher is smaller than the
    // student (scale artifact reported above).
    assert!(
        constants::STUDENT_BASE.params * 20 < constants::TEACHER_COLOR.params * 2,
        "paper-scale student must be ~10x+ smaller in parameters"
    );
    assert!(
        t1["student_opt"].macs * 3 < t1["student_base"].macs,
        "80% sparsity must cut effective MACs by >3x"
    );

    section("measured front-end latency (batch 8, deployed engine)");
    let mut p = Pipeline::new(&ServeConfig {
        artifacts_dir: "artifacts".into(),
        backend: Backend::FeatureCount,
        ..Default::default()
    })
    .unwrap();
    let s = meta.artifacts.image_size;
    let img = vec![0.1f32; 8 * s * s];
    let budget = Duration::from_secs(3);
    let student = bench_for(
        &format!("student features b8 ({})", p.engine_name()),
        2,
        10,
        budget,
        || {
            p.extract_features(&img, 8).unwrap();
        },
    );
    println!(
        "student front-end: {:.0} images/s (as-built teacher/student MAC ratio: {:.2}x)",
        8.0 * student.throughput(),
        meta.macs.as_built.teacher_gray.macs as f64 / meta.macs.as_built.student.macs as f64
    );
    println!("\ntable1_model_perf: PASS");
}

"""Training loops: teacher pre-training, baseline student training, and the
knowledge-distillation framework of Section II-A (Eq. 1-4) with curriculum
ordering.

Everything is hand-rolled functional JAX (no optax in this environment): Adam
state is a pytree zipped with the parameters, train steps are jitted once per
phase, and BatchNorm state threads through explicitly.
"""

from __future__ import annotations

import functools
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import DistillConfig, StudentConfig, TeacherConfig
from .model import student_logits, teacher_logits

# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, opt, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Losses (Eq. 1-3)
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def kd_loss(student_logits_, teacher_logits_, temperature):
    """Eq. 2: T^2 * KL(softmax(z_s/T) || softmax(z_t/T)).

    (Direction note: Hinton's formulation trains the student to match the
    teacher's softened distribution — cross-entropy with teacher targets —
    which is KL(teacher || student) up to the teacher's constant entropy;
    we use that standard form so gradients match the reference recipe.)
    """
    t_prob = jax.nn.softmax(teacher_logits_ / temperature)
    s_logp = jax.nn.log_softmax(student_logits_ / temperature)
    kl = jnp.sum(t_prob * (jnp.log(t_prob + 1e-9) - s_logp), axis=-1)
    return temperature ** 2 * jnp.mean(kl)


def composite_loss(s_logits, t_logits, labels, alpha, temperature):
    """Eq. 1: L = alpha * L_KD + (1 - alpha) * L_CE."""
    return alpha * kd_loss(s_logits, t_logits, temperature) + (1 - alpha) * cross_entropy(
        s_logits, labels
    )


# ---------------------------------------------------------------------------
# Generic epoch driver
# ---------------------------------------------------------------------------


def _batches(n, batch_size, rng: Optional[np.random.Generator], order=None):
    idx = np.arange(n) if order is None else np.asarray(order)
    if rng is not None:
        idx = rng.permutation(idx)
    for i in range(0, n - batch_size + 1, batch_size):
        yield idx[i : i + batch_size]


def evaluate(apply_fn, params, state, x, y, batch_size=200) -> float:
    """Top-1 accuracy of ``apply_fn(params, state, xb) -> logits``."""
    correct = 0
    for i in range(0, len(x), batch_size):
        logits = apply_fn(params, state, jnp.asarray(x[i : i + batch_size]))
        correct += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(y[i : i + batch_size])))
    return correct / len(x)


def eval_metrics(apply_fn, params, state, x, y, num_classes=10, batch_size=200):
    """Accuracy, macro F1/precision/recall and the confusion matrix — the
    Table I metric set."""
    cm = np.zeros((num_classes, num_classes), dtype=np.int64)
    for i in range(0, len(x), batch_size):
        logits = apply_fn(params, state, jnp.asarray(x[i : i + batch_size]))
        pred = np.asarray(jnp.argmax(logits, -1))
        for t, p in zip(y[i : i + batch_size], pred):
            cm[int(t), int(p)] += 1
    return confusion_metrics(cm)


def confusion_metrics(cm: np.ndarray) -> Dict:
    tp = np.diag(cm).astype(np.float64)
    support = cm.sum(axis=1).astype(np.float64)
    predicted = cm.sum(axis=0).astype(np.float64)
    prec = np.where(predicted > 0, tp / np.maximum(predicted, 1), 0.0)
    rec = np.where(support > 0, tp / np.maximum(support, 1), 0.0)
    f1 = np.where(prec + rec > 0, 2 * prec * rec / np.maximum(prec + rec, 1e-12), 0.0)
    return {
        "accuracy": float(tp.sum() / max(cm.sum(), 1)),
        "f1": float(f1.mean()),
        "precision": float(prec.mean()),
        "recall": float(rec.mean()),
        "per_class_accuracy": (tp / np.maximum(support, 1)).tolist(),
        "confusion": cm.tolist(),
    }


# ---------------------------------------------------------------------------
# Teacher pre-training
# ---------------------------------------------------------------------------


def train_teacher(cfg: TeacherConfig, params, state, tx, ty, vx, vy, log=None):
    log = log if log is not None else []

    @jax.jit
    def step(params, state, opt, xb, yb):
        def loss_fn(p):
            logits, new_s = teacher_logits(p, state, xb, cfg, training=True)
            from .model import l2_penalty

            return cross_entropy(logits, yb) + cfg.l2 * l2_penalty(p), new_s

        (loss, new_s), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = adam_update(params, grads, opt, cfg.lr)
        return params, new_s, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(cfg.seed)
    infer = jax.jit(lambda p, s, xb: teacher_logits(p, s, xb, cfg, training=False)[0])
    for epoch in range(cfg.epochs):
        t0 = time.time()
        losses = []
        for bidx in _batches(len(tx), cfg.batch_size, rng):
            params, state, opt, loss = step(
                params, state, opt, jnp.asarray(tx[bidx]), jnp.asarray(ty[bidx])
            )
            losses.append(float(loss))
        acc = evaluate(infer, params, state, vx, vy)
        log.append(
            {
                "phase": "teacher",
                "epoch": epoch,
                "loss": float(np.mean(losses)),
                "val_acc": acc,
                "secs": time.time() - t0,
            }
        )
    return params, state, log


# ---------------------------------------------------------------------------
# Student: baseline + knowledge distillation with curriculum (Eq. 4)
# ---------------------------------------------------------------------------


def train_student_baseline(cfg: StudentConfig, params, state, tx, ty, vx, vy, log=None):
    """Hard-label training — the "Student (without optimisations)" Table I row."""
    log = log if log is not None else []

    @jax.jit
    def step(params, state, opt, xb, yb):
        def loss_fn(p):
            logits, new_s = student_logits(p, state, xb, training=True)
            return cross_entropy(logits, yb), new_s

        (loss, new_s), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = adam_update(params, grads, opt, cfg.lr)
        return params, new_s, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(cfg.seed)
    infer = jax.jit(lambda p, s, xb: student_logits(p, s, xb, training=False)[0])
    for epoch in range(cfg.epochs):
        t0 = time.time()
        losses = []
        for bidx in _batches(len(tx), cfg.batch_size, rng):
            params, state, opt, loss = step(
                params, state, opt, jnp.asarray(tx[bidx]), jnp.asarray(ty[bidx])
            )
            losses.append(float(loss))
        acc = evaluate(infer, params, state, vx, vy)
        log.append(
            {
                "phase": "student_baseline",
                "epoch": epoch,
                "loss": float(np.mean(losses)),
                "val_acc": acc,
                "secs": time.time() - t0,
            }
        )
    return params, state, log


def curriculum_order(t_logits: np.ndarray, labels: np.ndarray) -> np.ndarray:
    """Eq. 4: order samples by teacher cross-entropy, easiest first."""
    logp = jax.nn.log_softmax(jnp.asarray(t_logits))
    d = -np.asarray(jnp.take_along_axis(logp, jnp.asarray(labels)[:, None], axis=1))[:, 0]
    return np.argsort(d, kind="stable")


def distill_student(
    dcfg: DistillConfig,
    scfg: StudentConfig,
    params,
    state,
    teacher_apply: Callable,  # xb -> teacher logits (frozen)
    tx,
    ty,
    vx,
    vy,
    log=None,
):
    """Knowledge distillation (Eq. 1-3) with curriculum ordering (Eq. 4).

    The teacher's logits over the whole training set are precomputed once:
    they define both the soft targets and the difficulty ordering.  Curriculum
    pacing: epoch e trains on the easiest fraction of the data (growing
    linearly from 60% to 100% over the curriculum phase), *shuffled within
    the subset* — strictly sorted batches destabilise BatchNorm statistics
    and can collapse training, so Eq. 4 selects *what* the student sees, not
    the literal batch order.
    """
    log = log if log is not None else []
    t_logits_all = []
    for i in range(0, len(tx), 256):
        t_logits_all.append(np.asarray(teacher_apply(jnp.asarray(tx[i : i + 256]))))
    t_logits_all = np.concatenate(t_logits_all)
    order = curriculum_order(t_logits_all, ty) if dcfg.curriculum else None

    @jax.jit
    def step(params, state, opt, xb, yb, tb):
        def loss_fn(p):
            logits, new_s = student_logits(p, state, xb, training=True)
            return composite_loss(logits, tb, yb, dcfg.alpha, dcfg.temperature), new_s

        (loss, new_s), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = adam_update(params, grads, opt, scfg.lr)
        return params, new_s, opt, loss

    opt = adam_init(params)
    rng = np.random.default_rng(scfg.seed + 17)
    infer = jax.jit(lambda p, s, xb: student_logits(p, s, xb, training=False)[0])
    for epoch in range(dcfg.epochs):
        t0 = time.time()
        losses = []
        curriculum_phase = dcfg.curriculum and epoch < max(dcfg.epochs // 2, 1)
        if curriculum_phase:
            # Easiest fraction grows 60% -> 100% across the curriculum phase.
            phase_len = max(dcfg.epochs // 2, 1)
            frac = 0.6 + 0.4 * (epoch + 1) / phase_len
            subset = order[: max(int(frac * len(tx)), scfg.batch_size)]
            batch_iter = _batches(len(subset), scfg.batch_size, rng, order=subset)
        else:
            batch_iter = _batches(len(tx), scfg.batch_size, rng)
        for bidx in batch_iter:
            params, state, opt, loss = step(
                params,
                state,
                opt,
                jnp.asarray(tx[bidx]),
                jnp.asarray(ty[bidx]),
                jnp.asarray(t_logits_all[bidx]),
            )
            losses.append(float(loss))
        acc = evaluate(infer, params, state, vx, vy)
        log.append(
            {
                "phase": "distill",
                "epoch": epoch,
                "curriculum": bool(curriculum_phase),
                "loss": float(np.mean(losses)),
                "val_acc": acc,
                "secs": time.time() - t0,
            }
        )
    return params, state, log

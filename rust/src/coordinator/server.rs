//! The serving loop: a dedicated worker thread owns the pipeline (the
//! engine trait object is not `Send` — PJRT handles cannot cross threads);
//! callers submit requests through a bounded channel (the backpressure
//! boundary) and wait on per-request oneshot channels, so multi-threaded
//! front-ends (and the CLI demo driver) compose naturally.

use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::error::{Error, Result};

use super::oneshot;

use super::batcher;
use super::metrics::Metrics;
use super::pipeline::{Classification, Pipeline};

/// One in-flight request.
struct Job {
    image: Vec<f32>,
    enqueued: Instant,
    resp: oneshot::Sender<Result<Classification>>,
}

/// Handle for submitting classification requests.
#[derive(Clone)]
pub struct Handle {
    tx: SyncSender<Job>,
    pub metrics: Arc<Metrics>,
    image_len: usize,
}

impl Handle {
    /// Submit an image; await the returned receiver for the result.
    /// Fails fast (backpressure) when the queue is full.
    pub fn submit(&self, image: Vec<f32>) -> Result<oneshot::Receiver<Result<Classification>>> {
        if image.len() != self.image_len {
            return Err(Error::Request(format!(
                "image has {} pixels, expected {}",
                image.len(),
                self.image_len
            )));
        }
        let (tx, rx) = oneshot::channel();
        self.metrics
            .requests
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        match self.tx.try_send(Job {
            image,
            enqueued: Instant::now(),
            resp: tx,
        }) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => {
                self.metrics
                    .errors
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Err(Error::Request("queue full (backpressure)".into()))
            }
            Err(TrySendError::Disconnected(_)) => {
                Err(Error::Request("server stopped".into()))
            }
        }
    }

    /// Convenience for synchronous callers: submit and block.
    pub fn classify_blocking(&self, image: Vec<f32>) -> Result<Classification> {
        let rx = self.submit(image)?;
        rx.recv()
            .map_err(|_| Error::Request("worker dropped response".into()))?
    }
}

/// The running server (worker thread + handle).
pub struct Server {
    pub handle: Handle,
    worker: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the worker thread.  The PJRT pipeline is **constructed inside
    /// the worker** (PJRT handles are not `Send`); construction failure is
    /// reported back through a ready-channel before `start` returns.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = sync_channel::<Job>(cfg.batch.queue_depth);
        let max_batch = cfg.batch.max_batch;
        let max_wait = Duration::from_micros(cfg.batch.max_wait_us);
        let m = Arc::clone(&metrics);
        let (ready_tx, ready_rx) = oneshot::channel::<Result<usize>>();

        let worker = std::thread::Builder::new()
            .name("hec-serve".into())
            .spawn(move || {
                use std::sync::atomic::Ordering::Relaxed;
                let mut pipeline = match Pipeline::new(&cfg) {
                    Ok(p) => {
                        let image_len = p.image_len();
                        let _ = ready_tx.send(Ok(image_len));
                        p
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let image_len = pipeline.image_len();
                while let Some(batch) = batcher::assemble(&rx, max_batch, max_wait) {
                    let n = batch.len();
                    m.batches.fetch_add(1, Relaxed);
                    m.batched_items.fetch_add(n as u64, Relaxed);

                    // Pack images contiguously.
                    let mut buf = Vec::with_capacity(n * image_len);
                    for job in &batch {
                        buf.extend_from_slice(&job.image);
                    }
                    let padded = pipeline.padding_for(n);
                    m.padded_slots.fetch_add(padded as u64, Relaxed);

                    let t0 = Instant::now();
                    let results = pipeline.classify_batch(&buf, n);
                    m.execute.record_us(t0.elapsed().as_micros() as u64);

                    match results {
                        Ok(results) => {
                            for (job, res) in batch.into_iter().zip(results) {
                                m.latency
                                    .record_us(job.enqueued.elapsed().as_micros() as u64);
                                m.add_energy_nj(res.energy_nj);
                                m.responses.fetch_add(1, Relaxed);
                                let _ = job.resp.send(Ok(res));
                            }
                        }
                        Err(e) => {
                            let msg = e.to_string();
                            for job in batch {
                                m.errors.fetch_add(1, Relaxed);
                                let _ = job.resp.send(Err(Error::Request(msg.clone())));
                            }
                        }
                    }
                }
            })
            .expect("spawn serving worker");

        let image_len = ready_rx
            .recv()
            .map_err(|_| Error::Request("serving worker died during startup".into()))??;
        Ok(Server {
            handle: Handle {
                tx,
                metrics,
                image_len,
            },
            worker: Some(worker),
        })
    }

    /// Stop accepting requests and join the worker.  (Outstanding `Handle`
    /// clones keep the channel open; the worker exits once the last clone
    /// drops.)
    pub fn shutdown(self) {
        let Server { handle, worker } = self;
        drop(handle);
        if let Some(w) = worker {
            let _ = w.join();
        }
    }
}

//! Open-loop tail-latency load harness against a live gateway.
//!
//! Drives the mixed-traffic schedule from `hec::loadgen` — Zipf hot-key
//! skew over a seeded image pool, bursts, slow/chunked clients,
//! per-request deadlines — at the HTTP front door, then reconciles three
//! views of the run into `BENCH_loadtest.json`:
//!
//! * **client-side** open-loop latency percentiles (p50/p90/p99/p99.9),
//!   measured from each request's *scheduled* arrival so server queueing
//!   under bursts is charged to the tail (no coordinated omission);
//! * **server-side** percentile upper bounds recovered from the
//!   `hec_latency_microseconds` histogram buckets on `/metrics`;
//! * **cache behaviour**: `hec_cache_{hits,misses}_total` before/after the
//!   run.  With Zipf skew and per-shard capacity >= pool, each shard can
//!   miss each distinct image at most once — the bench asserts that miss
//!   budget (equivalently, hit rate >= the Zipf-implied floor) and that
//!   hits actually skip the front-end.
//!
//! By default the harness boots its own in-process 3-shard gateway with
//! the feature cache enabled (artifact-free synthetic deployment).  Set
//! `HEC_LOADTEST_ADDR=host:port` to aim at an externally-booted server
//! (the CI `loadtest` job does this with the release binary) and
//! `HEC_LOADTEST_SHARDS` to its shard count (default 3; the miss budget
//! scales with it).  `HEC_BENCH_SMOKE=1` shrinks the schedule for CI;
//! `HEC_BENCH_OUT` overrides the report path.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use hec::benchkit::{self, section, BenchResult};
use hec::config::{Backend, HttpConfig, ServeConfig};
use hec::coordinator::ShardSet;
use hec::dataset::SyntheticDataset;
use hec::gateway::Gateway;
use hec::jsonlite::Value;
use hec::loadgen::{self, LoadgenConfig};
use hec::runtime::Meta;

const SHARDS: usize = 3;
const CACHE_CAPACITY: usize = 256;

/// One-shot GET over a fresh connection (for `/metrics`).
fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let req = format!("GET {path} HTTP/1.1\r\nHost: hec-loadtest\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes()).unwrap();
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        stream.read_exact(&mut byte).expect("response head");
        head.push(byte[0]);
        assert!(head.len() < 64 * 1024);
    }
    let head = String::from_utf8(head).unwrap();
    let status: u16 = head.split(' ').nth(1).unwrap().parse().unwrap();
    let content_length: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length")
                .then(|| v.trim().parse().unwrap())
        })
        .expect("Content-Length");
    let mut body = vec![0u8; content_length];
    stream.read_exact(&mut body).unwrap();
    (status, String::from_utf8_lossy(&body).into_owned())
}

/// Aggregate the `hec_latency_microseconds` cumulative buckets across
/// shard labels: `le upper edge -> cumulative count`.
fn latency_buckets(prom: &str) -> BTreeMap<u64, u64> {
    let mut by_le: BTreeMap<u64, u64> = BTreeMap::new();
    for line in prom.lines() {
        if !line.starts_with("hec_latency_microseconds_bucket") {
            continue;
        }
        let Some(le_start) = line.find("le=\"") else {
            continue;
        };
        let rest = &line[le_start + 4..];
        let Some(le_end) = rest.find('"') else {
            continue;
        };
        let le = match &rest[..le_end] {
            "+Inf" => u64::MAX,
            s => s.parse().unwrap_or(u64::MAX),
        };
        let Some(count) = line.rsplit(' ').next().and_then(|t| t.parse::<u64>().ok()) else {
            continue;
        };
        *by_le.entry(le).or_insert(0) += count;
    }
    by_le
}

/// Percentile upper bound from cumulative buckets: the smallest upper
/// edge whose cumulative count covers the rank (finite edges only; +Inf
/// falls back to the largest finite edge).
fn bucket_percentile(buckets: &BTreeMap<u64, u64>, q: f64) -> u64 {
    let total = buckets.values().max().copied().unwrap_or(0);
    if total == 0 {
        return 0;
    }
    let rank = (total as f64 * q).ceil() as u64;
    let mut last_finite = 0;
    for (&le, &cum) in buckets {
        if le != u64::MAX {
            last_finite = le;
        }
        if cum >= rank {
            return if le == u64::MAX { last_finite } else { le };
        }
    }
    last_finite
}

fn duration_row(name: &str, sorted_us: &[u64]) -> BenchResult {
    let n = sorted_us.len().max(1);
    let mean_us = sorted_us.iter().sum::<u64>() as f64 / n as f64;
    let at = |q: f64| Duration::from_micros(loadgen::percentile_us(sorted_us, q));
    BenchResult {
        name: name.to_string(),
        iters: sorted_us.len(),
        mean: Duration::from_secs_f64(mean_us / 1e6),
        p50: at(0.50),
        p99: at(0.99),
        min: Duration::from_micros(sorted_us.first().copied().unwrap_or(0)),
    }
}

fn main() {
    let smoke = std::env::var("HEC_BENCH_SMOKE").is_ok();
    let external = std::env::var("HEC_LOADTEST_ADDR").ok();
    let shards: usize = std::env::var("HEC_LOADTEST_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(SHARDS);

    let mut cfg = if smoke {
        LoadgenConfig::smoke()
    } else {
        LoadgenConfig::default()
    };
    // Keep the miss budget meaningful: capacity must cover the pool so a
    // shard can never evict a key it will see again.
    assert!(cfg.pool <= CACHE_CAPACITY);

    // Boot an in-process sharded gateway with the cache on, unless aimed
    // at an external server.
    let (addr, booted) = match &external {
        Some(a) => (a.parse::<SocketAddr>().expect("HEC_LOADTEST_ADDR"), None),
        None => {
            let mut sc = ServeConfig {
                artifacts_dir: "/nonexistent-hec-artifacts".into(),
                backend: Backend::FeatureCount,
                ..Default::default()
            };
            sc.shards.count = shards;
            sc.cache.enabled = true;
            sc.cache.capacity = CACHE_CAPACITY;
            sc.batch.max_batch = 8;
            sc.batch.max_wait_us = 500;
            let set = ShardSet::start(&sc).expect("boot shards");
            let gw = Gateway::start(
                set.handle.clone(),
                &HttpConfig {
                    addr: Some("127.0.0.1:0".to_string()),
                    max_connections: 64,
                },
            )
            .expect("boot gateway");
            (gw.local_addr(), Some((set, gw)))
        }
    };

    // Seeded image pool rendered once as JSON fragments — identical pool
    // slots produce byte-identical bodies, hence identical content hashes
    // server-side.
    let meta = Meta::load_or_synthetic("/nonexistent-hec-artifacts").unwrap();
    let ds = SyntheticDataset::new(
        cfg.seed ^ 0x9001,
        cfg.pool,
        meta.norm.mean as f32,
        meta.norm.std as f32,
    );
    let images_json: Vec<String> = (0..cfg.pool)
        .map(|i| loadgen::image_json(&ds.image(i)))
        .collect();

    let (_, before) = http_get(addr, "/metrics");
    let hits_before = loadgen::metric_total(&before, "hec_cache_hits_total");
    let misses_before = loadgen::metric_total(&before, "hec_cache_misses_total");

    section(&format!(
        "open-loop load: {} arrivals at ~{:.0} rps, pool {}, zipf {:.2}, {} shards{}",
        cfg.requests,
        cfg.rps,
        cfg.pool,
        cfg.zipf_s,
        shards,
        if external.is_some() { " (external)" } else { "" },
    ));
    cfg.workers = cfg.workers.max(4);
    let report = loadgen::run(addr, &cfg, &images_json);
    println!(
        "  outcomes: {} ok, {} http errors, {} deadline-exceeded, {} transport (of {})",
        report.ok,
        report.http_errors,
        report.deadline_exceeded,
        report.transport_errors,
        report.scheduled
    );
    println!(
        "  client e2e: p50 {} us, p90 {} us, p99 {} us, p99.9 {} us",
        report.e2e_us.p50, report.e2e_us.p90, report.e2e_us.p99, report.e2e_us.p999
    );

    let (_, after) = http_get(addr, "/metrics");
    let hits = loadgen::metric_total(&after, "hec_cache_hits_total") - hits_before;
    let misses = loadgen::metric_total(&after, "hec_cache_misses_total") - misses_before;
    let classified = hits + misses;
    let hit_rate = if classified > 0.0 { hits / classified } else { 0.0 };
    let floor = loadgen::hit_rate_floor(cfg.pool, shards, classified as usize);
    println!(
        "  cache: {hits:.0} hits / {misses:.0} misses (rate {:.1}%, floor {:.1}%)",
        hit_rate * 100.0,
        floor * 100.0
    );

    // Server-side percentile upper bounds from the histogram buckets.
    let buckets = latency_buckets(&after);
    let server_p = |q: f64| bucket_percentile(&buckets, q);
    println!(
        "  server (bucket upper bounds): p50 {} us, p90 {} us, p99 {} us, p99.9 {} us",
        server_p(0.50),
        server_p(0.90),
        server_p(0.99),
        server_p(0.999)
    );

    // ---- acceptance -----------------------------------------------------
    assert!(report.ok > 0, "no request succeeded");
    assert!(
        report.transport_errors == 0,
        "transport errors against a local gateway: {}",
        report.transport_errors
    );
    assert!(hits > 0.0, "Zipf skew must produce cache hits:\n{after}");
    assert!(
        misses <= (cfg.pool * shards) as f64,
        "each shard may miss each pool image at most once: \
         {misses:.0} misses > {} x {}",
        cfg.pool,
        shards
    );
    assert!(
        hit_rate >= floor,
        "hit rate {hit_rate:.3} below the Zipf-implied floor {floor:.3}"
    );

    // ---- report ---------------------------------------------------------
    let mut service: Vec<u64> = Vec::new();
    let mut e2e: Vec<u64> = Vec::new();
    // Percentiles are already folded; reconstruct representative rows from
    // the summary figures for the BenchResult table.
    for p in [
        report.service_us.p50,
        report.service_us.p90,
        report.service_us.p99,
        report.service_us.p999,
    ] {
        service.push(p);
    }
    for p in [
        report.e2e_us.p50,
        report.e2e_us.p90,
        report.e2e_us.p99,
        report.e2e_us.p999,
    ] {
        e2e.push(p);
    }
    let rows_owned = [
        duration_row("client_service_percentiles", &service),
        duration_row("client_e2e_percentiles", &e2e),
        duration_row(
            "server_bucket_percentiles",
            &[server_p(0.50), server_p(0.90), server_p(0.99), server_p(0.999)],
        ),
    ];
    let rows: Vec<&BenchResult> = rows_owned.iter().collect();
    let out = std::env::var("HEC_BENCH_OUT").unwrap_or_else(|_| "BENCH_loadtest.json".into());
    benchkit::write_json_report(
        &out,
        "hec/loadtest/v1",
        &[
            ("requests", Value::Num(cfg.requests as f64)),
            ("offered_rps", Value::Num(cfg.rps)),
            ("pool", Value::Num(cfg.pool as f64)),
            ("zipf_s", Value::Num(cfg.zipf_s)),
            ("shards", Value::Num(shards as f64)),
            ("cache_capacity", Value::Num(CACHE_CAPACITY as f64)),
            ("smoke", Value::Bool(smoke)),
            ("external", Value::Bool(external.is_some())),
            ("load", report.to_value()),
            ("cache_hits", Value::Num(hits)),
            ("cache_misses", Value::Num(misses)),
            ("cache_hit_rate", Value::Num(hit_rate)),
            ("cache_hit_rate_floor", Value::Num(floor)),
            ("server_p50_us", Value::Num(server_p(0.50) as f64)),
            ("server_p90_us", Value::Num(server_p(0.90) as f64)),
            ("server_p99_us", Value::Num(server_p(0.99) as f64)),
            ("server_p999_us", Value::Num(server_p(0.999) as f64)),
            (
                "row_semantics",
                Value::Str(
                    "rows summarise the percentile ladder (p50/p90/p99/p99.9) of each view; \
                     authoritative figures are the load/client_* and server_*_us extras"
                        .to_string(),
                ),
            ),
        ],
        &rows,
    )
    .expect("write BENCH_loadtest.json");
    println!("\nwrote {out}");

    if let Some((set, gw)) = booted {
        gw.shutdown();
        set.shutdown();
    }
    println!("loadtest: PASS (hit rate {:.1}% >= floor {:.1}%)", hit_rate * 100.0, floor * 100.0);
}
